//! PJRT execution engine: load HLO-text artifacts, compile on the CPU
//! client, execute dense models. Adapted from /opt/xla-example/load_hlo.
//!
//! Thread model: `xla::PjRtClient` is `Rc`-backed (not `Send`), so every
//! worker thread owns its own `Engine` and compiled executables. That
//! per-worker compile cost is the direct analog of funcX worker startup
//! (container pull + `pip install pyhf`), and is accounted the same way in
//! the scaling study (DESIGN.md §4).
//!
//! Feature gating: the `xla` crate is only present in vendored toolchains,
//! so the real engine compiles behind the `pjrt` feature. The default build
//! ships a stub whose constructors report unavailability — the coordinator,
//! scheduler, native fitter and simulator all keep working, and PJRT-backed
//! tests/benches skip cleanly. Errors are plain `String`s (the offline build
//! carries no error-handling crates).

use std::path::Path;

use crate::histfactory::dense::DenseModel;
use crate::infer::results::PointResult;
use crate::runtime::manifest::ArtifactEntry;

/// A PJRT CPU client (stubbed out unless built with `--features pjrt`).
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
}

/// A PJRT CPU client (stubbed out unless built with `--features pjrt`).
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    _private: (),
}

/// A compiled artifact bound to its manifest entry.
pub struct Compiled {
    pub entry: ArtifactEntry,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

/// Parsed outputs of a hypotest artifact execution (OUTPUT_ORDER contract).
#[derive(Debug, Clone)]
pub struct HypotestOut {
    pub cls_obs: f64,
    pub cls_exp: [f64; 5],
    pub qmu: f64,
    pub qmu_a: f64,
    pub mu_hat: f64,
    pub nll_free: f64,
    pub nll_fixed: f64,
    /// (accepted steps, |grad|) per fit, 4 fits
    pub diag: [f64; 8],
}

#[cfg(not(feature = "pjrt"))]
const UNAVAILABLE: &str = "PJRT engine unavailable: built without the 'pjrt' feature \
     (vendored xla crate not present); use the native backend";

#[cfg(feature = "pjrt")]
impl Engine {
    pub fn cpu() -> Result<Engine, String> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()
                .map_err(|e| format!("create PJRT CPU client: {e:?}"))?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact from `dir`.
    pub fn load(&self, entry: &ArtifactEntry, dir: &Path) -> Result<Compiled, String> {
        let path = entry.path(dir);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| "non-utf8 artifact path".to_string())?,
        )
        .map_err(|e| format!("parse HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {}: {e:?}", path.display()))?;
        Ok(Compiled { entry: entry.clone(), exe })
    }
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    pub fn cpu() -> Result<Engine, String> {
        Err(UNAVAILABLE.to_string())
    }

    pub fn platform(&self) -> String {
        "unavailable (pjrt feature disabled)".to_string()
    }

    /// Load + compile one artifact from `dir`.
    pub fn load(&self, _entry: &ArtifactEntry, _dir: &Path) -> Result<Compiled, String> {
        Err(UNAVAILABLE.to_string())
    }
}

#[cfg(feature = "pjrt")]
impl Compiled {
    /// Execute with the dense model's tensors; returns flattened f64 outputs
    /// in OUTPUT_ORDER.
    pub fn execute_raw(&self, inputs: &[(&str, &[f64])]) -> Result<Vec<Vec<f64>>, String> {
        // marshal in manifest order, validating names and lengths
        if inputs.len() != self.entry.inputs.len() {
            return Err(format!(
                "artifact '{}' expects {} inputs, got {}",
                self.entry.key,
                self.entry.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (name, data)) in inputs.iter().enumerate() {
            let (want_name, want_shape) = &self.entry.inputs[i];
            if want_name != name {
                return Err(format!(
                    "input {i} of '{}' must be '{want_name}', got '{name}'",
                    self.entry.key
                ));
            }
            let want_len: usize = want_shape.iter().product::<usize>().max(1);
            if data.len() != want_len {
                return Err(format!(
                    "input '{name}' of '{}' expects {want_len} elements, got {}",
                    self.entry.key,
                    data.len()
                ));
            }
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = want_shape.iter().map(|&d| d as i64).collect();
            let lit = if dims.len() > 1 {
                lit.reshape(&dims).map_err(|e| format!("reshape literal: {e:?}"))?
            } else {
                lit
            };
            literals.push(lit);
        }

        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format!("execute artifact: {e:?}"))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch result literal: {e:?}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| format!("decompose output tuple: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            out.push(part.to_vec::<f64>().map_err(|e| format!("read f64 output: {e:?}"))?);
        }
        Ok(out)
    }

    /// Execute the hypotest artifact against a compiled dense model.
    pub fn hypotest(&self, model: &DenseModel) -> Result<HypotestOut, String> {
        let views = model.input_views();
        let outs = self.execute_raw(&views)?;
        if outs.len() != 8 {
            return Err(format!("hypotest artifact returned {} outputs, want 8", outs.len()));
        }
        let scalar = |i: usize| -> f64 { outs[i][0] };
        let mut cls_exp = [0.0; 5];
        cls_exp.copy_from_slice(&outs[1][..5]);
        let mut diag = [0.0; 8];
        diag.copy_from_slice(&outs[7][..8]);
        Ok(HypotestOut {
            cls_obs: scalar(0),
            cls_exp,
            qmu: scalar(2),
            qmu_a: scalar(3),
            mu_hat: scalar(4),
            nll_free: scalar(5),
            nll_fixed: scalar(6),
            diag,
        })
    }

    /// Execute the MLE artifact: returns (theta_hat, nll, diag).
    pub fn mle(&self, model: &DenseModel) -> Result<(Vec<f64>, f64, Vec<f64>), String> {
        let views = model.input_views();
        let outs = self.execute_raw(&views)?;
        if outs.len() != 3 {
            return Err(format!("mle artifact returned {} outputs, want 3", outs.len()));
        }
        Ok((outs[0].clone(), outs[1][0], outs[2].clone()))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Compiled {
    /// Execute with the dense model's tensors (stub: always unavailable).
    pub fn execute_raw(&self, _inputs: &[(&str, &[f64])]) -> Result<Vec<Vec<f64>>, String> {
        Err(UNAVAILABLE.to_string())
    }

    /// Execute the hypotest artifact (stub: always unavailable).
    pub fn hypotest(&self, _model: &DenseModel) -> Result<HypotestOut, String> {
        Err(UNAVAILABLE.to_string())
    }

    /// Execute the MLE artifact (stub: always unavailable).
    pub fn mle(&self, _model: &DenseModel) -> Result<(Vec<f64>, f64, Vec<f64>), String> {
        Err(UNAVAILABLE.to_string())
    }
}

/// CPU fallback for the artifact hot path: run the full qmu-tilde
/// hypotest with the native fused kernel, shaped like an artifact
/// execution ([`HypotestOut`]). `scratch` is the worker's per-class
/// [`FitScratch`]; reusing it across calls makes the steady state
/// allocation-free per NLL evaluation, exactly like a warm compiled
/// executable. This is what serves fits when the `pjrt` feature (and so
/// the real engine) is absent.
pub fn native_hypotest(
    model: &DenseModel,
    scratch: &mut crate::fitter::FitScratch,
    mu_test: f64,
) -> HypotestOut {
    let owned = std::mem::take(scratch);
    let fitter = crate::fitter::NativeFitter::with_scratch(model, owned);
    let h = fitter.hypotest(mu_test);
    *scratch = fitter.into_scratch();
    HypotestOut {
        cls_obs: h.cls_obs,
        cls_exp: h.cls_exp,
        qmu: h.qmu,
        qmu_a: h.qmu_a,
        mu_hat: h.mu_hat,
        nll_free: h.nll_free,
        nll_fixed: h.nll_fixed,
        diag: h.diag,
    }
}

impl HypotestOut {
    /// Convert to a scan point result.
    pub fn to_point(&self, patch: &str, values: Vec<f64>, fit_seconds: f64) -> PointResult {
        PointResult {
            patch: patch.to_string(),
            values,
            cls_obs: self.cls_obs,
            cls_exp: self.cls_exp,
            qmu: self.qmu,
            qmu_a: self.qmu_a,
            mu_hat: self.mu_hat,
            fit_seconds,
        }
    }
}
