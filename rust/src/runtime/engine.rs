//! PJRT execution engine: load HLO-text artifacts, compile on the CPU
//! client, execute dense models. Adapted from /opt/xla-example/load_hlo.
//!
//! Thread model: `xla::PjRtClient` is `Rc`-backed (not `Send`), so every
//! worker thread owns its own `Engine` and compiled executables. That
//! per-worker compile cost is the direct analog of funcX worker startup
//! (container pull + `pip install pyhf`), and is accounted the same way in
//! the scaling study (DESIGN.md §4).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::histfactory::dense::DenseModel;
use crate::infer::results::PointResult;
use crate::runtime::manifest::ArtifactEntry;

/// A PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
}

/// A compiled artifact bound to its manifest entry.
pub struct Compiled {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// Parsed outputs of a hypotest artifact execution (OUTPUT_ORDER contract).
#[derive(Debug, Clone)]
pub struct HypotestOut {
    pub cls_obs: f64,
    pub cls_exp: [f64; 5],
    pub qmu: f64,
    pub qmu_a: f64,
    pub mu_hat: f64,
    pub nll_free: f64,
    pub nll_fixed: f64,
    /// (accepted steps, |grad|) per fit, 4 fits
    pub diag: [f64; 8],
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact from `dir`.
    pub fn load(&self, entry: &ArtifactEntry, dir: &Path) -> Result<Compiled> {
        let path = entry.path(dir);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Compiled { entry: entry.clone(), exe })
    }
}

impl Compiled {
    /// Execute with the dense model's tensors; returns flattened f64 outputs
    /// in OUTPUT_ORDER.
    pub fn execute_raw(&self, inputs: &[(&str, &[f64])]) -> Result<Vec<Vec<f64>>> {
        // marshal in manifest order, validating names and lengths
        if inputs.len() != self.entry.inputs.len() {
            return Err(anyhow!(
                "artifact '{}' expects {} inputs, got {}",
                self.entry.key,
                self.entry.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (name, data)) in inputs.iter().enumerate() {
            let (want_name, want_shape) = &self.entry.inputs[i];
            if want_name != name {
                return Err(anyhow!(
                    "input {i} of '{}' must be '{want_name}', got '{name}'",
                    self.entry.key
                ));
            }
            let want_len: usize = want_shape.iter().product::<usize>().max(1);
            if data.len() != want_len {
                return Err(anyhow!(
                    "input '{name}' of '{}' expects {want_len} elements, got {}",
                    self.entry.key,
                    data.len()
                ));
            }
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = want_shape.iter().map(|&d| d as i64).collect();
            let lit = if dims.len() > 1 {
                lit.reshape(&dims).context("reshape literal")?
            } else {
                lit
            };
            literals.push(lit);
        }

        let result = self.exe.execute::<xla::Literal>(&literals).context("execute artifact")?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.decompose_tuple().context("decompose output tuple")?;
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            out.push(part.to_vec::<f64>().context("read f64 output")?);
        }
        Ok(out)
    }

    /// Execute the hypotest artifact against a compiled dense model.
    pub fn hypotest(&self, model: &DenseModel) -> Result<HypotestOut> {
        let views = model.input_views();
        let outs = self.execute_raw(&views)?;
        if outs.len() != 8 {
            return Err(anyhow!("hypotest artifact returned {} outputs, want 8", outs.len()));
        }
        let scalar = |i: usize| -> f64 { outs[i][0] };
        let mut cls_exp = [0.0; 5];
        cls_exp.copy_from_slice(&outs[1][..5]);
        let mut diag = [0.0; 8];
        diag.copy_from_slice(&outs[7][..8]);
        Ok(HypotestOut {
            cls_obs: scalar(0),
            cls_exp,
            qmu: scalar(2),
            qmu_a: scalar(3),
            mu_hat: scalar(4),
            nll_free: scalar(5),
            nll_fixed: scalar(6),
            diag,
        })
    }

    /// Execute the MLE artifact: returns (theta_hat, nll, diag).
    pub fn mle(&self, model: &DenseModel) -> Result<(Vec<f64>, f64, Vec<f64>)> {
        let views = model.input_views();
        let outs = self.execute_raw(&views)?;
        if outs.len() != 3 {
            return Err(anyhow!("mle artifact returned {} outputs, want 3", outs.len()));
        }
        Ok((outs[0].clone(), outs[1][0], outs[2].clone()))
    }
}

impl HypotestOut {
    /// Convert to a scan point result.
    pub fn to_point(&self, patch: &str, values: Vec<f64>, fit_seconds: f64) -> PointResult {
        PointResult {
            patch: patch.to_string(),
            values,
            cls_obs: self.cls_obs,
            cls_exp: self.cls_exp,
            qmu: self.qmu,
            qmu_a: self.qmu_a,
            mu_hat: self.mu_hat,
            fit_seconds,
        }
    }
}
