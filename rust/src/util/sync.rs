//! Poison-recovering lock helpers for the serving path.
//!
//! `std`'s `Mutex::lock()` returns `Err` only when another thread panicked
//! while holding the guard. The serving fabric's state (task table, queue,
//! metrics, trace buffers, journal) stays structurally valid across such a
//! panic — every critical section either completes its update or leaves
//! counters merely stale — so the right response for a service is to keep
//! serving with the inner value, not to cascade the panic into every other
//! worker/client thread that touches the lock. These extension traits make
//! that recovery a one-word idiom (`.lock_unpoisoned()`), which the
//! `no_panic` rule of `tools/pallas-lint` requires on the hot path in place
//! of `.lock().unwrap()`.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// `Mutex` extension: acquire, recovering the guard from a poisoned lock.
pub trait MutexExt<T: ?Sized> {
    /// Like [`Mutex::lock`], but a panic in another critical section does
    /// not propagate: the poisoned guard is unwrapped and returned.
    fn lock_unpoisoned(&self) -> MutexGuard<'_, T>;
}

impl<T: ?Sized> MutexExt<T> for Mutex<T> {
    fn lock_unpoisoned(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// `Condvar` extension: waits that recover the guard from a poisoned lock.
pub trait CondvarExt {
    /// Like [`Condvar::wait`], recovering from poison.
    fn wait_unpoisoned<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T>;

    /// Like [`Condvar::wait_timeout`], recovering from poison.
    fn wait_timeout_unpoisoned<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult);
}

impl CondvarExt for Condvar {
    fn wait_unpoisoned<'a, T>(&self, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
        self.wait(guard).unwrap_or_else(PoisonError::into_inner)
    }

    fn wait_timeout_unpoisoned<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
        self.wait_timeout(guard, dur).unwrap_or_else(PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_unpoisoned_recovers_after_holder_panics() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            let _g = m2.lock_unpoisoned();
            panic!("poison the lock");
        });
        assert!(t.join().is_err());
        assert!(m.lock().is_err(), "lock should be poisoned");
        assert_eq!(*m.lock_unpoisoned(), 7);
        *m.lock_unpoisoned() = 8;
        assert_eq!(*m.lock_unpoisoned(), 8);
    }

    #[test]
    fn condvar_waits_still_wake() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = pair.clone();
        let t = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut g = m.lock_unpoisoned();
            while !*g {
                g = cv.wait_unpoisoned(g);
            }
            *g
        });
        std::thread::sleep(Duration::from_millis(10));
        let (m, cv) = &*pair;
        *m.lock_unpoisoned() = true;
        cv.notify_all();
        assert!(t.join().unwrap());
    }

    #[test]
    fn wait_timeout_unpoisoned_times_out() {
        let pair = (Mutex::new(()), Condvar::new());
        let g = pair.0.lock_unpoisoned();
        let (_g, res) = pair.1.wait_timeout_unpoisoned(g, Duration::from_millis(5));
        assert!(res.timed_out());
    }
}
