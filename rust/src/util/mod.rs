//! From-scratch utility substrates (the offline crate set is the `xla`
//! closure only, so JSON, RNG, CLI parsing, thread pools, statistics and
//! property-test helpers are all built here).

pub mod cli;
pub mod json;
pub mod logging;
pub mod lru;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
