//! Tiny leveled logger with wall-clock-relative timestamps, mirroring the
//! task-stream output style of the paper's Listing 2.
//!
//! Output is sink-pluggable: the default [`StderrSink`] prints the classic
//! `[   0.123s INFO  target] msg` lines, [`JsonSink`] emits one JSON
//! object per line (the `--log-json` CLI flag), and [`CaptureSink`] keeps
//! records in memory so tests can assert on log output instead of it
//! vanishing to stderr.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    /// Fixed-width tag used by the stderr format.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }

    /// Lowercase name used by the JSONL format.
    pub fn name(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

/// Session-start reference for relative timestamps.
fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

/// One log record as handed to a sink.
#[derive(Debug, Clone)]
pub struct Record {
    /// seconds since the logger's session start
    pub t_s: f64,
    pub level: Level,
    pub target: String,
    pub msg: String,
}

/// Where formatted records go.
pub trait LogSink: Send + Sync {
    fn write(&self, record: &Record);
}

/// Default sink: the classic human-readable stderr lines.
pub struct StderrSink;

impl LogSink for StderrSink {
    fn write(&self, r: &Record) {
        eprintln!("[{:9.3}s {} {}] {}", r.t_s, r.level.tag(), r.target, r.msg);
    }
}

/// Structured sink: one JSON object per stderr line (machine-ingestible;
/// enabled by the `--log-json` CLI flag).
pub struct JsonSink;

impl LogSink for JsonSink {
    fn write(&self, r: &Record) {
        let line = Json::obj(vec![
            ("t_s", Json::num(r.t_s)),
            ("level", Json::str(r.level.name())),
            ("target", Json::str(r.target.clone())),
            ("msg", Json::str(r.msg.clone())),
        ]);
        eprintln!("{}", crate::util::json::to_string(&line));
    }
}

/// Test sink: records accumulate in memory until taken.
#[derive(Default)]
pub struct CaptureSink {
    records: Mutex<Vec<Record>>,
}

impl CaptureSink {
    pub fn new() -> Arc<CaptureSink> {
        Arc::new(CaptureSink::default())
    }

    /// Drain everything captured so far.
    pub fn take(&self) -> Vec<Record> {
        std::mem::take(&mut *self.records.lock().unwrap())
    }
}

impl LogSink for CaptureSink {
    fn write(&self, r: &Record) {
        self.records.lock().unwrap().push(r.clone());
    }
}

fn sink_slot() -> &'static Mutex<Arc<dyn LogSink>> {
    static SINK: OnceLock<Mutex<Arc<dyn LogSink>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Arc::new(StderrSink)))
}

/// Install a sink (returns the previous one, so tests can restore it).
pub fn set_sink(sink: Arc<dyn LogSink>) -> Arc<dyn LogSink> {
    let mut slot = sink_slot().lock().unwrap();
    std::mem::replace(&mut *slot, sink)
}

pub fn log(level: Level, target: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let record = Record {
        t_s: start().elapsed().as_secs_f64(),
        level,
        target: target.to_string(),
        msg: msg.to_string(),
    };
    let sink = sink_slot().lock().unwrap().clone();
    sink.write(&record);
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }

    #[test]
    fn capture_sink_sees_records() {
        set_level(Level::Info);
        let capture = CaptureSink::new();
        let previous = set_sink(capture.clone());
        crate::log_warn!("logging-test", "captured {}", 42);
        set_sink(previous);
        let records: Vec<Record> =
            capture.take().into_iter().filter(|r| r.target == "logging-test").collect();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].level, Level::Warn);
        assert_eq!(records[0].msg, "captured 42");
        assert!(records[0].t_s >= 0.0);
    }

    #[test]
    fn json_record_shape_is_valid_json() {
        // format what JsonSink would emit and parse it back
        let r = Record {
            t_s: 1.5,
            level: Level::Error,
            target: "svc".into(),
            msg: "task \"x\" failed".into(),
        };
        let line = Json::obj(vec![
            ("t_s", Json::num(r.t_s)),
            ("level", Json::str(r.level.name())),
            ("target", Json::str(r.target.clone())),
            ("msg", Json::str(r.msg.clone())),
        ]);
        let parsed = crate::util::json::parse(&crate::util::json::to_string(&line)).unwrap();
        assert_eq!(parsed.get("level").unwrap().as_str(), Some("error"));
        assert_eq!(parsed.get("msg").unwrap().as_str(), Some("task \"x\" failed"));
    }
}
