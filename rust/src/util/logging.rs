//! Tiny leveled logger with wall-clock-relative timestamps, mirroring the
//! task-stream output style of the paper's Listing 2.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

/// Session-start reference for relative timestamps.
fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, target: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match level {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{t:9.3}s {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
