//! Summary statistics for trial aggregation (Table 1 reports mean ± std over
//! 10 trials) plus simple streaming counters/histograms for the coordinator's
//! metrics.

/// Aggregate of a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator), 0 for n < 2.
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (pct / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Log-bucketed quantile sketch bounds: 10 decades from 1 µs up to 10 ks
/// at 8 buckets per decade — fixed memory (80 counters) regardless of how
/// many observations stream through, with worst-case relative quantile
/// error of one bucket width (10^(1/8) ≈ 1.33x), tightened by clamping to
/// the observed min/max.
const QLOG_LO: f64 = 1e-6;
const QLOG_PER_DECADE: usize = 8;
const QLOG_DECADES: usize = 10;
const QLOG_BUCKETS: usize = QLOG_PER_DECADE * QLOG_DECADES;

/// Streaming mean/min/max accumulator (Welford variance) with a
/// fixed-memory log-bucketed histogram for p50/p95/p99 quantiles.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    /// log-bucket counters, lazily sized to [`QLOG_BUCKETS`] on first push
    qlog: Vec<u64>,
    qlog_under: u64,
    qlog_over: u64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            qlog: Vec::new(),
            qlog_under: 0,
            qlog_over: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.qlog.is_empty() {
            self.qlog = vec![0; QLOG_BUCKETS];
        }
        if !(x >= QLOG_LO) {
            // below range, zero, negative or NaN: count once at the floor
            self.qlog_under += 1;
        } else {
            let i = ((x / QLOG_LO).log10() * QLOG_PER_DECADE as f64) as usize;
            if i >= QLOG_BUCKETS {
                self.qlog_over += 1;
            } else {
                self.qlog[i] += 1;
            }
        }
    }

    /// Rank-`q` quantile estimate from the log-bucketed histogram
    /// (`q` in [0, 1]; 0.0 when nothing was pushed). Within the located
    /// bucket the estimate is its geometric midpoint, clamped to the
    /// exactly-tracked min/max.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut seen = self.qlog_under;
        if seen >= rank {
            return self.min;
        }
        for (i, &c) in self.qlog.iter().enumerate() {
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= rank {
                let lo = QLOG_LO * 10f64.powf(i as f64 / QLOG_PER_DECADE as f64);
                let hi = lo * 10f64.powf(1.0 / QLOG_PER_DECADE as f64);
                return (lo * hi).sqrt().clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n > 1 {
            (self.m2 / (self.n - 1) as f64).sqrt()
        } else {
            0.0
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-bin histogram over [lo, hi) with overflow/underflow counts.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0 }
    }

    pub fn fill(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let i = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[i.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - 1.5811388300841898).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.5);
        assert_eq!(s.p95, 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile_sorted(&xs, 50.0), 5.0);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn accumulator_matches_summary() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x);
        }
        let s = Summary::of(&xs);
        assert!((acc.mean() - s.mean).abs() < 1e-10);
        assert!((acc.std() - s.std).abs() < 1e-10);
        assert_eq!(acc.min(), s.min);
        assert_eq!(acc.max(), s.max);
        assert_eq!(acc.count(), 100);
    }

    #[test]
    fn quantiles_track_known_distributions() {
        // uniform 1..=1000 ms: p50 ≈ 0.5 s, p95 ≈ 0.95 s, p99 ≈ 0.99 s,
        // each within one log-bucket width (10^(1/8) ≈ 1.33x)
        let mut acc = Accumulator::new();
        for i in 1..=1000 {
            acc.push(i as f64 * 1e-3);
        }
        for (q, expect) in [(0.50, 0.5), (0.95, 0.95), (0.99, 0.99)] {
            let got = acc.quantile(q);
            assert!(
                got / expect > 0.7 && got / expect < 1.4,
                "q{q}: got {got}, expected ~{expect}"
            );
        }
        let q0 = acc.quantile(0.0);
        assert!(q0 >= acc.min() && q0 <= acc.min() * 1.4, "q0 {q0} vs min {}", acc.min());
        assert!(acc.quantile(1.0) <= acc.max());
    }

    #[test]
    fn quantiles_handle_edge_inputs() {
        let empty = Accumulator::new();
        assert_eq!(empty.quantile(0.5), 0.0);
        // constant sample: every quantile is that constant (clamped to
        // min/max even though the bucket midpoint differs)
        let mut acc = Accumulator::new();
        for _ in 0..50 {
            acc.push(2.5);
        }
        assert_eq!(acc.p50(), 2.5);
        assert_eq!(acc.p99(), 2.5);
        // out-of-range values fall into under/overflow but stay ranked
        let mut acc = Accumulator::new();
        acc.push(0.0); // under the 1 µs floor
        acc.push(1e9); // over the 10 ks ceiling
        assert_eq!(acc.quantile(0.1), 0.0);
        assert_eq!(acc.quantile(0.9), 1e9);
        // default-constructed accumulators lazily allocate the sketch
        let mut acc = Accumulator::default();
        acc.push(0.25);
        assert!(acc.p95() > 0.0);
    }

    #[test]
    fn histogram_fills() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.6, 9.99, -1.0, 10.0, 25.0] {
            h.fill(x);
        }
        assert_eq!(h.bins[0], 1);
        assert_eq!(h.bins[1], 2);
        assert_eq!(h.bins[9], 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert_eq!(h.total(), 7);
    }
}
