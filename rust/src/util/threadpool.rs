//! Fixed-size worker thread pool over std channels.
//!
//! The coordinator's HighThroughputExecutor runs funcX "workers" as pool
//! threads (the offline crate set has no tokio; explicit threads also mirror
//! Parsl's process-worker model more faithfully than an async runtime would).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads consuming jobs from a shared queue.
pub struct ThreadPool {
    tx: Sender<Msg>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` workers named `{name}-{i}`.
    pub fn new(name: &str, size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let msg = {
                        let guard = rx.lock().expect("pool queue poisoned");
                        guard.recv()
                    };
                    match msg {
                        Ok(Msg::Run(job)) => job(),
                        Ok(Msg::Shutdown) | Err(_) => break,
                    }
                })
                .expect("spawn pool worker");
            handles.push(handle);
        }
        ThreadPool { tx, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a job; panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx.send(Msg::Run(Box::new(job))).expect("pool is shut down");
    }

    /// Signal shutdown and join all workers (runs remaining queued jobs first,
    /// since each worker drains the queue until it sees a Shutdown message).
    pub fn shutdown(mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Convenience: run `f` over `items` with `workers` threads, preserving order
/// of results.
pub fn parallel_map<T, R, F>(workers: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return vec![];
    }
    let pool = ThreadPool::new("pmap", workers.max(1));
    let f = Arc::new(f);
    let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
    for (i, item) in items.into_iter().enumerate() {
        let f = Arc::clone(&f);
        let tx = tx.clone();
        pool.execute(move || {
            let r = f(item);
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx.iter() {
        out[i] = Some(r);
    }
    pool.shutdown();
    out.into_iter().map(|r| r.expect("worker dropped result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new("t", 4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(3, (0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(2, Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new("d", 2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop here must join, running all 10 jobs
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
