//! From-scratch JSON: value model, parser, serializer, RFC 6901 pointers and
//! RFC 6902 patches.
//!
//! HistFactory workspaces are JSON documents and pyhf *patchsets* are literal
//! JSON Patch operations, so this is a core substrate of the reproduction
//! (and the offline crate set has no serde_json). Object key order is
//! preserved — patch round-trips must not reshuffle workspaces.

use std::fmt;

/// A JSON value. Numbers are f64 (HistFactory rates/counts are doubles).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Errors from parsing, pointer resolution or patch application.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    /// Byte offset for parse errors, if known.
    pub at: Option<usize>,
}

impl JsonError {
    fn new(msg: impl Into<String>) -> Self {
        JsonError { msg: msg.into(), at: None }
    }
    fn at(msg: impl Into<String>, pos: usize) -> Self {
        JsonError { msg: msg.into(), at: Some(pos) }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.at {
            Some(p) => write!(f, "json error at byte {}: {}", p, self.msg),
            None => write!(f, "json error: {}", self.msg),
        }
    }
}

impl std::error::Error for JsonError {}

pub type Result<T> = std::result::Result<T, JsonError>;

// ---------------------------------------------------------------------------
// accessors
// ---------------------------------------------------------------------------

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(v) => v.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        match self {
            Json::Obj(v) => v.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array index lookup.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        self.as_arr().and_then(|a| a.get(i))
    }

    /// Field as f64 array; errors if missing or mistyped.
    pub fn f64_array(&self, key: &str) -> Result<Vec<f64>> {
        let arr = self
            .get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| JsonError::new(format!("missing array field '{key}'")))?;
        arr.iter()
            .map(|v| v.as_f64().ok_or_else(|| JsonError::new(format!("non-number in '{key}'"))))
            .collect()
    }

    /// Insert or replace an object field.
    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(v) = self {
            if let Some(slot) = v.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val;
            } else {
                v.push((key.to_string(), val));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { bytes: s.as_bytes(), pos: 0 }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(JsonError::at(format!("expected '{}'", b as char), self.pos))
        }
    }

    fn parse_value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.parse_obj(),
            Some(b'[') => self.parse_arr(),
            Some(b'"') => Ok(Json::Str(self.parse_string()?)),
            Some(b't') => self.parse_lit("true", Json::Bool(true)),
            Some(b'f') => self.parse_lit("false", Json::Bool(false)),
            Some(b'n') => self.parse_lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.parse_num(),
            Some(c) => Err(JsonError::at(format!("unexpected byte '{}'", c as char), self.pos)),
            None => Err(JsonError::at("unexpected end of input", self.pos)),
        }
    }

    fn parse_lit(&mut self, lit: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(JsonError::at(format!("expected '{lit}'"), self.pos))
        }
    }

    fn parse_num(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| JsonError::at("bad utf8 in number", start))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::at(format!("bad number '{text}'"), start))
    }

    fn parse_string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(JsonError::at("unterminated string", self.pos)),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.parse_hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(JsonError::at("lone high surrogate", self.pos));
                            }
                            let lo = self.parse_hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(JsonError::at("bad low surrogate", self.pos));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(ch.ok_or_else(|| JsonError::at("bad codepoint", self.pos))?);
                    }
                    _ => return Err(JsonError::at("bad escape", self.pos)),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-decode multibyte utf8 from the raw input
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(JsonError::at("bad utf8", start)),
                    };
                    let end = start + width;
                    let s = self
                        .bytes
                        .get(start..end)
                        .and_then(|b| std::str::from_utf8(b).ok())
                        .ok_or_else(|| JsonError::at("bad utf8", start))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn parse_hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| JsonError::at("eof in \\u", self.pos))?;
            let d = (c as char).to_digit(16).ok_or_else(|| JsonError::at("bad hex", self.pos))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn parse_arr(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(JsonError::at("expected ',' or ']'", self.pos)),
            }
        }
    }

    fn parse_obj(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.parse_value()?;
            out.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(JsonError::at("expected ',' or '}'", self.pos)),
            }
        }
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(s: &str) -> Result<Json> {
    let mut p = Parser::new(s);
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(JsonError::at("trailing input", p.pos));
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// serializer
// ---------------------------------------------------------------------------

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // shortest round-trip repr rust gives us
        out.push_str(&format!("{x}"));
    }
}

fn write_value(v: &Json, indent: Option<usize>, level: usize, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (level + 1)));
                }
                write_value(item, indent, level + 1, out);
            }
            if indent.is_some() && !items.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent.unwrap() * level));
            }
            out.push(']');
        }
        Json::Obj(pairs) => {
            out.push('{');
            for (i, (k, item)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (level + 1)));
                }
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(item, indent, level + 1, out);
            }
            if indent.is_some() && !pairs.is_empty() {
                out.push('\n');
                out.push_str(&" ".repeat(indent.unwrap() * level));
            }
            out.push('}');
        }
    }
}

/// Compact serialization.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, None, 0, &mut out);
    out
}

/// Pretty serialization with 2-space indent.
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, Some(2), 0, &mut out);
    out
}

// ---------------------------------------------------------------------------
// RFC 6901 JSON Pointer
// ---------------------------------------------------------------------------

/// Split and unescape a JSON pointer into reference tokens.
pub fn pointer_tokens(ptr: &str) -> Result<Vec<String>> {
    if ptr.is_empty() {
        return Ok(vec![]);
    }
    if !ptr.starts_with('/') {
        return Err(JsonError::new(format!("pointer must start with '/': {ptr}")));
    }
    Ok(ptr[1..]
        .split('/')
        .map(|t| t.replace("~1", "/").replace("~0", "~"))
        .collect())
}

/// Resolve a pointer to a reference.
pub fn pointer<'a>(doc: &'a Json, ptr: &str) -> Result<&'a Json> {
    let mut cur = doc;
    for tok in pointer_tokens(ptr)? {
        cur = match cur {
            Json::Obj(_) => cur
                .get(&tok)
                .ok_or_else(|| JsonError::new(format!("pointer: missing key '{tok}'")))?,
            Json::Arr(items) => {
                let i: usize = tok
                    .parse()
                    .map_err(|_| JsonError::new(format!("pointer: bad index '{tok}'")))?;
                items
                    .get(i)
                    .ok_or_else(|| JsonError::new(format!("pointer: index {i} out of range")))?
            }
            _ => return Err(JsonError::new("pointer: descended into scalar")),
        };
    }
    Ok(cur)
}

// ---------------------------------------------------------------------------
// RFC 6902 JSON Patch
// ---------------------------------------------------------------------------

enum Loc<'a> {
    ObjField(&'a mut Json, String),
    ArrIdx(&'a mut Json, usize),
    ArrEnd(&'a mut Json),
    Root,
}

/// Navigate to the parent of the pointer target; returns where the final
/// token lands.
fn locate<'a>(doc: &'a mut Json, ptr: &str) -> Result<Loc<'a>> {
    let toks = pointer_tokens(ptr)?;
    if toks.is_empty() {
        return Ok(Loc::Root);
    }
    let (last, parents) = toks.split_last().unwrap();
    let mut cur = doc;
    for tok in parents {
        let next = match cur {
            Json::Obj(_) => cur
                .get_mut(tok)
                .ok_or_else(|| JsonError::new(format!("patch path: missing key '{tok}'")))?,
            Json::Arr(items) => {
                let i: usize = tok
                    .parse()
                    .map_err(|_| JsonError::new(format!("patch path: bad index '{tok}'")))?;
                items
                    .get_mut(i)
                    .ok_or_else(|| JsonError::new(format!("patch path: index {i} out of range")))?
            }
            _ => return Err(JsonError::new("patch path: descended into scalar")),
        };
        cur = next;
    }
    match cur {
        Json::Obj(_) => Ok(Loc::ObjField(cur, last.clone())),
        Json::Arr(_) if last == "-" => Ok(Loc::ArrEnd(cur)),
        Json::Arr(_) => {
            let i: usize = last
                .parse()
                .map_err(|_| JsonError::new(format!("patch path: bad index '{last}'")))?;
            Ok(Loc::ArrIdx(cur, i))
        }
        _ => Err(JsonError::new("patch path: parent is a scalar")),
    }
}

/// Apply one RFC 6902 operation in place.
pub fn apply_op(doc: &mut Json, op: &Json) -> Result<()> {
    let kind = op
        .get("op")
        .and_then(|v| v.as_str())
        .ok_or_else(|| JsonError::new("patch op missing 'op'"))?
        .to_string();
    let path = op
        .get("path")
        .and_then(|v| v.as_str())
        .ok_or_else(|| JsonError::new("patch op missing 'path'"))?
        .to_string();

    let fetch_value = |op: &Json| -> Result<Json> {
        op.get("value").cloned().ok_or_else(|| JsonError::new("patch op missing 'value'"))
    };

    match kind.as_str() {
        "add" => {
            let value = fetch_value(op)?;
            match locate(doc, &path)? {
                Loc::Root => *doc = value,
                Loc::ObjField(parent, key) => parent.set(&key, value),
                Loc::ArrEnd(parent) => {
                    if let Json::Arr(items) = parent {
                        items.push(value)
                    }
                }
                Loc::ArrIdx(parent, i) => {
                    if let Json::Arr(items) = parent {
                        if i > items.len() {
                            return Err(JsonError::new(format!("add: index {i} out of range")));
                        }
                        items.insert(i, value);
                    }
                }
            }
        }
        "replace" => {
            let value = fetch_value(op)?;
            match locate(doc, &path)? {
                Loc::Root => *doc = value,
                Loc::ObjField(parent, key) => {
                    parent
                        .get_mut(&key)
                        .map(|slot| *slot = value)
                        .ok_or_else(|| JsonError::new(format!("replace: missing key '{key}'")))?;
                }
                Loc::ArrEnd(_) => return Err(JsonError::new("replace: '-' not allowed")),
                Loc::ArrIdx(parent, i) => {
                    if let Json::Arr(items) = parent {
                        *items
                            .get_mut(i)
                            .ok_or_else(|| JsonError::new(format!("replace: index {i} out of range")))? = value;
                    }
                }
            }
        }
        "remove" => match locate(doc, &path)? {
            Loc::Root => return Err(JsonError::new("remove: cannot remove root")),
            Loc::ObjField(parent, key) => {
                if let Json::Obj(pairs) = parent {
                    let before = pairs.len();
                    pairs.retain(|(k, _)| k != &key);
                    if pairs.len() == before {
                        return Err(JsonError::new(format!("remove: missing key '{key}'")));
                    }
                }
            }
            Loc::ArrEnd(_) => return Err(JsonError::new("remove: '-' not allowed")),
            Loc::ArrIdx(parent, i) => {
                if let Json::Arr(items) = parent {
                    if i >= items.len() {
                        return Err(JsonError::new(format!("remove: index {i} out of range")));
                    }
                    items.remove(i);
                }
            }
        },
        "test" => {
            let value = fetch_value(op)?;
            let actual = pointer(doc, &path)?;
            if *actual != value {
                return Err(JsonError::new(format!("test failed at '{path}'")));
            }
        }
        "copy" | "move" => {
            let from = op
                .get("from")
                .and_then(|v| v.as_str())
                .ok_or_else(|| JsonError::new("patch op missing 'from'"))?
                .to_string();
            let value = pointer(doc, &from)?.clone();
            if kind == "move" {
                apply_op(doc, &Json::obj(vec![("op", Json::str("remove")), ("path", Json::str(from))]))?;
            }
            apply_op(
                doc,
                &Json::obj(vec![
                    ("op", Json::str("add")),
                    ("path", Json::str(path)),
                    ("value", value),
                ]),
            )?;
        }
        other => return Err(JsonError::new(format!("unsupported patch op '{other}'"))),
    }
    Ok(())
}

/// Apply a full RFC 6902 patch (array of ops) in place; atomicity is the
/// caller's concern (clone first if needed).
pub fn apply_patch(doc: &mut Json, patch: &Json) -> Result<()> {
    let ops = patch.as_arr().ok_or_else(|| JsonError::new("patch must be an array"))?;
    for op in ops {
        apply_op(doc, op)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\nb\t\"q\" é 😀 ü""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" é 😀 ü"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} trailing").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"w": {"xs": [1, 2.5, -3e-2], "s": "a\"b", "n": null, "t": true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    }

    #[test]
    fn number_formatting_preserves_integers() {
        assert_eq!(to_string(&Json::Num(125.0)), "125");
        assert_eq!(to_string(&Json::Num(0.5)), "0.5");
    }

    #[test]
    fn object_key_order_preserved() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        assert_eq!(to_string(&v), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn pointer_resolution() {
        let v = parse(r#"{"a": {"b": [10, 20]}, "x~y": 1, "p/q": 2}"#).unwrap();
        assert_eq!(pointer(&v, "/a/b/1").unwrap(), &Json::Num(20.0));
        assert_eq!(pointer(&v, "/x~0y").unwrap(), &Json::Num(1.0));
        assert_eq!(pointer(&v, "/p~1q").unwrap(), &Json::Num(2.0));
        assert_eq!(pointer(&v, "").unwrap(), &v);
        assert!(pointer(&v, "/a/z").is_err());
        assert!(pointer(&v, "a/b").is_err());
    }

    #[test]
    fn patch_add_replace_remove() {
        let mut v = parse(r#"{"channels": [{"name": "SR"}]}"#).unwrap();
        let patch = parse(
            r#"[
            {"op": "add", "path": "/channels/-", "value": {"name": "CR"}},
            {"op": "replace", "path": "/channels/0/name", "value": "SR2"},
            {"op": "add", "path": "/version", "value": "1.0.0"}
        ]"#,
        )
        .unwrap();
        apply_patch(&mut v, &patch).unwrap();
        assert_eq!(pointer(&v, "/channels/1/name").unwrap().as_str(), Some("CR"));
        assert_eq!(pointer(&v, "/channels/0/name").unwrap().as_str(), Some("SR2"));
        let rm = parse(r#"[{"op": "remove", "path": "/channels/0"}]"#).unwrap();
        apply_patch(&mut v, &rm).unwrap();
        assert_eq!(pointer(&v, "/channels/0/name").unwrap().as_str(), Some("CR"));
    }

    #[test]
    fn patch_test_copy_move() {
        let mut v = parse(r#"{"a": 1, "b": {"c": 2}}"#).unwrap();
        let p = parse(
            r#"[
            {"op": "test", "path": "/a", "value": 1},
            {"op": "copy", "from": "/b/c", "path": "/d"},
            {"op": "move", "from": "/a", "path": "/b/e"}
        ]"#,
        )
        .unwrap();
        apply_patch(&mut v, &p).unwrap();
        assert_eq!(pointer(&v, "/d").unwrap(), &Json::Num(2.0));
        assert_eq!(pointer(&v, "/b/e").unwrap(), &Json::Num(1.0));
        assert!(v.get("a").is_none());
    }

    #[test]
    fn patch_test_failure_reported() {
        let mut v = parse(r#"{"a": 1}"#).unwrap();
        let p = parse(r#"[{"op": "test", "path": "/a", "value": 2}]"#).unwrap();
        assert!(apply_patch(&mut v, &p).is_err());
    }

    #[test]
    fn patch_array_insert_mid() {
        let mut v = parse("[1,3]").unwrap();
        apply_patch(&mut v, &parse(r#"[{"op":"add","path":"/1","value":2}]"#).unwrap()).unwrap();
        assert_eq!(to_string(&v), "[1,2,3]");
        assert!(apply_patch(&mut v, &parse(r#"[{"op":"add","path":"/9","value":0}]"#).unwrap()).is_err());
    }
}
