//! Tiny bounded LRU containers (no external crates offline).
//!
//! Worker warm state — compiled PJRT executables, fit scratch workspaces,
//! affinity keys — must not grow without bound on a long-lived endpoint
//! serving many shape classes (ROADMAP "warm-state eviction"). Capacities
//! are small (a handful of shape classes), so a `Vec` in recency order
//! beats a linked-map: O(cap) scans with perfect cache locality.

use std::borrow::Borrow;

/// Bounded key-value cache with least-recently-used eviction. Recency
/// order: index 0 is the LRU entry, the back is the MRU entry.
#[derive(Debug, Clone)]
pub struct LruCache<K, V> {
    cap: usize,
    entries: Vec<(K, V)>,
}

impl<K: PartialEq, V> LruCache<K, V> {
    pub fn new(cap: usize) -> LruCache<K, V> {
        LruCache { cap: cap.max(1), entries: Vec::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains<Q>(&self, k: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: PartialEq + ?Sized,
    {
        self.entries.iter().any(|(key, _)| key.borrow() == k)
    }

    /// Refresh `k` to most-recently-used; true if it was present.
    pub fn touch<Q>(&mut self, k: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: PartialEq + ?Sized,
    {
        match self.entries.iter().position(|(key, _)| key.borrow() == k) {
            Some(i) => {
                let e = self.entries.remove(i);
                self.entries.push(e);
                true
            }
            None => false,
        }
    }

    /// Fetch `k`, refreshing it to most-recently-used.
    pub fn get<Q>(&mut self, k: &Q) -> Option<&V>
    where
        K: Borrow<Q>,
        Q: PartialEq + ?Sized,
    {
        if self.touch(k) {
            self.entries.last().map(|(_, v)| v)
        } else {
            None
        }
    }

    /// Remove and return the value under `k` (no eviction bookkeeping).
    pub fn take<Q>(&mut self, k: &Q) -> Option<V>
    where
        K: Borrow<Q>,
        Q: PartialEq + ?Sized,
    {
        let i = self.entries.iter().position(|(key, _)| key.borrow() == k)?;
        Some(self.entries.remove(i).1)
    }

    /// Insert (or refresh) `k`; returns the evicted LRU entry when the
    /// cache overflows its capacity.
    pub fn put(&mut self, k: K, v: V) -> Option<(K, V)> {
        if let Some(i) = self.entries.iter().position(|(key, _)| *key == k) {
            self.entries.remove(i);
        }
        self.entries.push((k, v));
        if self.entries.len() > self.cap {
            Some(self.entries.remove(0))
        } else {
            None
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &K> {
        self.entries.iter().map(|(k, _)| k)
    }
}

/// Bounded set with least-recently-used eviction (an [`LruCache`] with
/// unit values).
///
/// This is the container behind every warm set in the fabric: a worker's
/// compiled shape classes, an endpoint's routed affinity keys, the sim's
/// per-worker executable caches.
///
/// ```
/// use pyhf_faas::util::lru::LruSet;
///
/// let mut warm = LruSet::new(2);
/// assert!(warm.insert("1Lbb").is_none());
/// assert!(warm.insert("2L0J").is_none());
/// warm.touch("1Lbb"); // refresh: "2L0J" is now least recently used
/// assert_eq!(warm.insert("stau"), Some("2L0J"));
/// assert!(warm.contains("1Lbb") && !warm.contains("2L0J"));
/// ```
#[derive(Debug, Clone)]
pub struct LruSet<K> {
    cache: LruCache<K, ()>,
}

impl<K: PartialEq> LruSet<K> {
    pub fn new(cap: usize) -> LruSet<K> {
        LruSet { cache: LruCache::new(cap) }
    }

    pub fn capacity(&self) -> usize {
        self.cache.capacity()
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    pub fn contains<Q>(&self, k: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: PartialEq + ?Sized,
    {
        self.cache.contains(k)
    }

    /// Refresh `k` to most-recently-used; true if it was present.
    pub fn touch<Q>(&mut self, k: &Q) -> bool
    where
        K: Borrow<Q>,
        Q: PartialEq + ?Sized,
    {
        self.cache.touch(k)
    }

    /// Insert (or refresh) `k`; returns the evicted key on overflow.
    pub fn insert(&mut self, k: K) -> Option<K> {
        self.cache.put(k, ()).map(|(key, ())| key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_and_evict_lru_order() {
        let mut c: LruCache<String, u32> = LruCache::new(2);
        assert!(c.put("a".to_string(), 1).is_none());
        assert!(c.put("b".to_string(), 2).is_none());
        // touching "a" makes "b" the LRU victim
        assert_eq!(c.get("a"), Some(&1));
        let evicted = c.put("c".to_string(), 3).unwrap();
        assert_eq!(evicted.0, "b");
        assert!(c.contains("a") && c.contains("c") && !c.contains("b"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn put_refreshes_existing_key() {
        let mut c: LruCache<String, u32> = LruCache::new(2);
        c.put("a".into(), 1);
        c.put("b".into(), 2);
        // re-putting "a" refreshes it instead of evicting
        assert!(c.put("a".into(), 10).is_none());
        assert_eq!(c.get("a"), Some(&10));
        let evicted = c.put("c".into(), 3).unwrap();
        assert_eq!(evicted.0, "b");
    }

    #[test]
    fn take_removes_without_eviction() {
        let mut c: LruCache<String, u32> = LruCache::new(4);
        c.put("a".into(), 1);
        assert_eq!(c.take("a"), Some(1));
        assert_eq!(c.take("a"), None);
        assert!(c.is_empty());
    }

    #[test]
    fn set_insert_contains_and_evicts() {
        let mut s: LruSet<usize> = LruSet::new(2);
        assert!(s.insert(1).is_none());
        assert!(s.insert(2).is_none());
        assert!(s.touch(&1));
        assert_eq!(s.insert(3), Some(2));
        assert!(s.contains(&1) && s.contains(&3) && !s.contains(&2));
        assert_eq!(s.len(), 2);
        // duplicate insert refreshes, never evicts
        assert!(s.insert(1).is_none());
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut s: LruSet<u8> = LruSet::new(0);
        assert_eq!(s.capacity(), 1);
        assert!(s.insert(1).is_none());
        assert_eq!(s.insert(2), Some(1));
    }
}
