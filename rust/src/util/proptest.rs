//! Miniature property-testing harness (no proptest crate offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` over `cases` generated
//! inputs; on failure it performs a bounded shrink search by re-generating
//! with "smaller" generator budgets and reports the smallest failing case.

use crate::util::rng::Rng;

/// Controls generator sizes; shrinking lowers `size` toward 1.
#[derive(Debug)]
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size.max(1));
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.f64() < 0.5
    }
}

/// Run a property over generated cases. `generate` must be deterministic in
/// the Gen it receives. Panics with the smallest failing case found.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut generate: G, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> bool,
{
    let mut failing: Option<(u64, usize, T)> = None;
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let mut gen = Gen { rng: &mut rng, size: 64 };
        let input = generate(&mut gen);
        if !prop(&input) {
            failing = Some((case_seed, 64, input));
            break;
        }
    }

    if let Some((case_seed, _, worst)) = failing {
        // bounded shrink: retry the same stream with smaller size budgets
        let mut smallest = worst.clone();
        for size in [32, 16, 8, 4, 2, 1] {
            let mut rng = Rng::new(case_seed);
            let mut gen = Gen { rng: &mut rng, size };
            let candidate = generate(&mut gen);
            if !prop(&candidate) {
                smallest = candidate;
            }
        }
        panic!(
            "property failed (seed {case_seed:#x}); smallest failing case: {smallest:?}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        forall(1, 200, |g| {
            let len = g.usize_in(0, 10);
            g.vec_f64(len, -5.0, 5.0)
        }, |xs| {
            xs.iter().sum::<f64>().abs() <= 5.0 * xs.len() as f64 + 1e-12
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        forall(2, 100, |g| g.usize_in(0, 50), |&n| n < 10);
    }
}
