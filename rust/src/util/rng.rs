//! Deterministic RNG substrate: splitmix64 seeding + xoshiro256++ core,
//! with the distribution samplers the workload generators need (uniform,
//! normal, Poisson, exponential). No external crates; reproducible across
//! platforms (pure integer/f64 arithmetic).

/// xoshiro256++ PRNG (Blackman & Vigna), seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal variate from Box-Muller
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-worker / per-channel RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift bounded sampling (Lemire); bias < 2^-64, fine here
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * th.sin());
        r * th.cos()
    }

    /// Normal with mean/std.
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson sampler: Knuth product for small mean, normal approximation
    /// (continuity-corrected, clamped) for large mean.
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
                if k > 10_000 {
                    return k; // numerically impossible; guard anyway
                }
            }
        }
        let z = self.normal();
        (mean + z * mean.sqrt() + 0.5).max(0.0) as u64
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut buckets = [0usize; 10];
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            buckets[(x * 10.0) as usize] += 1;
        }
        for b in buckets {
            assert!((700..1300).contains(&b), "bucket {b} too skewed");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_moments_small_and_large_mean() {
        let mut r = Rng::new(11);
        for mean in [0.5, 4.0, 80.0] {
            let n = 20_000;
            let xs: Vec<f64> = (0..n).map(|_| r.poisson(mean) as f64).collect();
            let m = xs.iter().sum::<f64>() / n as f64;
            let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n as f64;
            assert!((m - mean).abs() < 0.05 * mean + 0.05, "mean {m} vs {mean}");
            assert!((v - mean).abs() < 0.1 * mean + 0.1, "var {v} vs {mean}");
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
