//! Minimal command-line argument parser (offline crate set has no clap).
//!
//! Supports `command [--flag] [--key value] [positional...]` with typed
//! accessors and generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key-value options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]). `known_flags`
    /// lists boolean options; everything else starting with `--` consumes a
    /// value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if known_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    let val = iter
                        .next()
                        .ok_or_else(|| format!("option --{name} expects a value"))?;
                    out.options.insert(name.to_string(), val);
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name} expects an integer, got '{s}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name} expects a number, got '{s}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| format!("--{name} expects an integer, got '{s}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn commands_options_flags_positionals() {
        let a = parse(
            &["scan", "--patches", "125", "--verbose", "--out=res.json", "pallet-dir"],
            &["verbose"],
        );
        assert_eq!(a.command.as_deref(), Some("scan"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("patches"), Some("125"));
        assert_eq!(a.get("out"), Some("res.json"));
        assert_eq!(a.positional, vec!["pallet-dir"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["x", "--n", "12", "--r", "1.5"], &[]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 12);
        assert_eq!(a.get_f64("r", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("r", 0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--n".to_string()], &[]).is_err());
    }
}
