//! Scheduler integration tests: each dispatch policy exercised through the
//! real coordinator (service + endpoint + executor threads), the shutdown
//! drain guarantee, and the sim-driven check that warm-worker affinity
//! beats FIFO on warm-start latency at paper scale.
//!
//! Determinism pattern: tests that assert on dispatch *order* gate worker
//! startup behind an `AtomicBool` in `worker_init`, so the whole wave is
//! queued before the first pop.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use pyhf_faas::coordinator::{
    Endpoint, EndpointConfig, ExecutorConfig, FaasClient, Service, ServiceHandle, TaskState,
};
use pyhf_faas::scheduler::{PolicyKind, RouteStrategyKind, Router, WarmFirstRoute};
use pyhf_faas::sim::{
    simulate_policy, table1_mixed_workload, CostModel, SimPolicy, Topology,
};
use pyhf_faas::util::json::Json;

fn single_worker_exec() -> ExecutorConfig {
    ExecutorConfig {
        max_blocks: 1,
        nodes_per_block: 1,
        workers_per_node: 1,
        parallelism: 1.0,
        poll: Duration::from_millis(1),
    }
}

/// Endpoint whose (single) worker blocks in init until `gate` is released.
fn gated_endpoint(svc: &ServiceHandle, policy: PolicyKind, gate: Arc<AtomicBool>) -> Endpoint {
    Endpoint::start(
        svc.clone(),
        EndpointConfig::new(format!("gated-{}", policy.as_str()))
            .with_executor(single_worker_exec())
            .with_policy(policy)
            .with_worker_init(Arc::new(move |_ctx: &mut _| {
                while !gate.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Ok(())
            })),
    )
}

/// Handler that appends its payload `tag` to a shared log.
fn recording_handler(
    log: Arc<Mutex<Vec<String>>>,
) -> pyhf_faas::coordinator::service::Handler {
    Arc::new(move |p: &Json, _ctx: &mut _| {
        let tag = p.get("tag").and_then(|v| v.as_str()).unwrap_or("?").to_string();
        log.lock().unwrap().push(tag);
        Ok(Json::Null)
    })
}

#[test]
fn fifo_preserves_submission_order() {
    let svc = Service::new();
    let gate = Arc::new(AtomicBool::new(false));
    let ep = gated_endpoint(&svc, PolicyKind::Fifo, gate.clone());
    let log = Arc::new(Mutex::new(Vec::new()));
    let f = svc.register_function("record", recording_handler(log.clone()));

    let ids: Vec<_> = (0..10)
        .map(|i| {
            svc.submit(ep.id, f, Json::obj(vec![("tag", Json::str(format!("t{i}")))])).unwrap()
        })
        .collect();
    gate.store(true, Ordering::SeqCst);
    for id in ids {
        svc.wait_result(id, Duration::from_secs(10)).unwrap();
    }
    let order = log.lock().unwrap().clone();
    let expect: Vec<String> = (0..10).map(|i| format!("t{i}")).collect();
    assert_eq!(order, expect);
    ep.shutdown();
}

#[test]
fn priority_policy_runs_high_priority_first() {
    let svc = Service::new();
    let gate = Arc::new(AtomicBool::new(false));
    let ep = gated_endpoint(&svc, PolicyKind::Priority, gate.clone());
    let log = Arc::new(Mutex::new(Vec::new()));
    let f = svc.register_function("record", recording_handler(log.clone()));

    // three low-priority tasks submitted BEFORE three high-priority ones
    let mut ids = Vec::new();
    for i in 0..3 {
        ids.push(
            svc.submit(
                ep.id,
                f,
                Json::obj(vec![
                    ("tag", Json::str(format!("low{i}"))),
                    ("priority", Json::num(0.0)),
                ]),
            )
            .unwrap(),
        );
    }
    for i in 0..3 {
        ids.push(
            svc.submit(
                ep.id,
                f,
                Json::obj(vec![
                    ("tag", Json::str(format!("high{i}"))),
                    ("priority", Json::num(9.0)),
                ]),
            )
            .unwrap(),
        );
    }
    gate.store(true, Ordering::SeqCst);
    for id in ids {
        svc.wait_result(id, Duration::from_secs(10)).unwrap();
    }
    let order = log.lock().unwrap().clone();
    assert_eq!(order, vec!["high0", "high1", "high2", "low0", "low1", "low2"]);
    ep.shutdown();
}

#[test]
fn affinity_policy_groups_classes_and_hits() {
    // interleaved classes A,B,C,A,B,C,... through one affinity worker: the
    // worker must serve each class as one contiguous run (2 switches for 3
    // classes instead of 35 under FIFO), and the endpoint's hit counters
    // must show a warm stream
    let svc = Service::new();
    let gate = Arc::new(AtomicBool::new(false));
    let ep = gated_endpoint(&svc, PolicyKind::Affinity, gate.clone());
    let log = Arc::new(Mutex::new(Vec::new()));
    let colds = Arc::new(AtomicUsize::new(0));
    let f = {
        let log = log.clone();
        let colds = colds.clone();
        svc.register_function(
            "classy",
            Arc::new(move |p: &Json, ctx: &mut _| {
                let class = p.get("class").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                let slot = format!("warm:{class}");
                if ctx.get::<bool>(&slot).is_none() {
                    // cold start: "compile" the executable for this class
                    colds.fetch_add(1, Ordering::SeqCst);
                    ctx.insert(&slot, true);
                }
                log.lock().unwrap().push(class);
                Ok(Json::Null)
            }),
        )
    };

    let classes = ["A", "B", "C"];
    let ids: Vec<_> = (0..36)
        .map(|i| {
            svc.submit(
                ep.id,
                f,
                Json::obj(vec![("class", Json::str(classes[i % 3]))]),
            )
            .unwrap()
        })
        .collect();
    gate.store(true, Ordering::SeqCst);
    for id in ids {
        svc.wait_result(id, Duration::from_secs(10)).unwrap();
    }

    let order = log.lock().unwrap().clone();
    assert_eq!(order.len(), 36);
    let switches = order.windows(2).filter(|w| w[0] != w[1]).count();
    assert!(
        switches <= 2,
        "affinity should serve classes contiguously, saw {switches} switches: {order:?}"
    );
    assert_eq!(colds.load(Ordering::SeqCst), 3, "one cold start per class");

    let m = ep.metrics_snapshot();
    assert_eq!(m.affinity_hits + m.affinity_misses, 36);
    // first pop of each class is a miss; everything else must be warm
    assert_eq!(m.affinity_misses, 3, "hits {} misses {}", m.affinity_hits, m.affinity_misses);
    assert!(m.affinity_hit_rate() > 0.9);
    ep.shutdown();
}

#[test]
fn shutdown_drains_all_queued_tasks() {
    // the satellite fix: Endpoint::shutdown must let workers finish every
    // queued task (the seed raced shutdown and dropped them)
    let svc = Service::new();
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("drain")
            .with_executor(single_worker_exec())
            .with_worker_init(Arc::new(|_| Ok(()))),
    );
    let f = svc.register_function(
        "slow",
        Arc::new(|p: &Json, _| {
            std::thread::sleep(Duration::from_millis(8));
            Ok(p.clone())
        }),
    );
    let ids: Vec<_> = (0..8).map(|i| svc.submit(ep.id, f, Json::num(i as f64)).unwrap()).collect();
    // wait for the worker, then shut down with most of the wave still queued
    let t0 = std::time::Instant::now();
    while ep.active_workers() == 0 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(2));
    }
    ep.shutdown();
    for id in &ids {
        assert_eq!(svc.task_state(*id), Some(TaskState::Success), "task {id} was dropped");
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.completed, 8);
    assert_eq!(m.failed, 0);
}

#[test]
fn batched_wave_through_real_endpoint() {
    // batching + affinity end-to-end: a deduped, coalesced wave through a
    // batch-aware echo function on an affinity endpoint
    let svc = Service::new();
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("batched")
            .with_executor(ExecutorConfig {
                max_blocks: 1,
                nodes_per_block: 1,
                workers_per_node: 2,
                parallelism: 1.0,
                poll: Duration::from_millis(1),
            })
            .with_policy(PolicyKind::Affinity),
    );
    let client = FaasClient::new(svc.clone());
    let f = client.register_function(
        "echo",
        pyhf_faas::scheduler::batched_handler(Arc::new(|p: &Json, _| Ok(p.clone()))),
    );
    let mk = |name: &str, class: &str| {
        Json::obj(vec![("patch", Json::str(name)), ("class", Json::str(class))])
    };
    let payloads = vec![
        mk("a0", "A"),
        mk("b0", "B"),
        mk("a0", "A"), // duplicate
        mk("a1", "A"),
        mk("b1", "B"),
    ];
    let sub = client.run_coalesced(&payloads, ep.id, f, 4).unwrap();
    // 4 uniques -> one A-batch (a0, a1) + one B-batch (b0, b1)
    assert_eq!(sub.tasks.len(), 2);
    let group_results = client
        .gather(&sub.tasks, Duration::from_secs(10), Duration::from_millis(1), None, |_, _| {})
        .unwrap();
    let results = sub.unpack(&group_results).unwrap();
    assert_eq!(results.len(), 5);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.as_ref().unwrap(), &payloads[i]);
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.dedup_hits, 1);
    assert_eq!(m.batches, 2);
    assert_eq!(m.batched_tasks, 4);
    ep.shutdown();
}

#[test]
fn warm_first_router_spills_to_cold_endpoint_when_saturated() {
    // two single-worker sites behind the cross-endpoint router, workers
    // gated so the whole wave routes against queued backlog: warm-first
    // keeps class A on the first site until its backlog exceeds the spill
    // margin, then steers overflow to the cold site — after the gate opens
    // both sites run their share of the work
    let svc = Service::new();
    let gate = Arc::new(AtomicBool::new(false));
    let ep0 = gated_endpoint(&svc, PolicyKind::Affinity, gate.clone());
    let ep1 = gated_endpoint(&svc, PolicyKind::Affinity, gate.clone());

    let mut router = Router::with_strategy(Box::new(WarmFirstRoute::with_margin(2.0)));
    router.add_target(ep0.id, 0, ep0.probe());
    router.add_target(ep1.id, 1, ep1.probe());
    svc.install_router(router);
    assert_eq!(svc.route_strategy_name(), Some("warm_first"));

    let client = FaasClient::new(svc.clone());
    let f = client.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));

    let p0 = ep0.probe();
    let p1 = ep1.probe();
    let ids: Vec<_> = (0..12)
        .map(|i| {
            client
                .run_routed(
                    Json::obj(vec![("n", Json::num(i as f64)), ("class", Json::str("A"))]),
                    f,
                )
                .unwrap()
        })
        .collect();

    // routing happened against gated (all-queued) backlog: the warm site
    // filled to the margin, then work spilled to the cold site
    assert!(p0.queued_weight() > 0, "warm site got nothing");
    assert!(p1.queued_weight() > 0, "saturated warm site never spilled");
    let m = svc.metrics.snapshot();
    assert_eq!(m.routed, 12);
    assert!(m.route_warm_hits >= 4, "warm hits {}", m.route_warm_hits);
    assert!(m.route_spillovers >= 1, "spillovers {}", m.route_spillovers);

    gate.store(true, Ordering::SeqCst);
    for (i, id) in ids.iter().enumerate() {
        let r = svc.wait_result(*id, Duration::from_secs(10)).unwrap();
        assert_eq!(r.get("n").unwrap().as_f64(), Some(i as f64));
    }
    // both interchanges actually dispatched work
    let s0 = ep0.metrics_snapshot();
    let s1 = ep1.metrics_snapshot();
    assert!(s0.affinity_hits + s0.affinity_misses > 0);
    assert!(s1.affinity_hits + s1.affinity_misses > 0);
    ep0.shutdown();
    ep1.shutdown();
}

#[test]
fn routed_coalesced_wave_spans_endpoints_and_restores_order() {
    // round-robin routing of a deduped + coalesced wave across two live
    // endpoints: results come back in submission order regardless of site
    let svc = Service::new();
    let mk_ep = |name: &str| {
        Endpoint::start(
            svc.clone(),
            EndpointConfig::new(name)
                .with_executor(single_worker_exec())
                .with_policy(PolicyKind::Affinity),
        )
    };
    let ep0 = mk_ep("site0");
    let ep1 = mk_ep("site1");
    let mut router = Router::new(RouteStrategyKind::RoundRobin);
    router.add_target(ep0.id, 0, ep0.probe());
    router.add_target(ep1.id, 1, ep1.probe());
    svc.install_router(router);

    let client = FaasClient::new(svc.clone());
    let f = client.register_function(
        "echo",
        pyhf_faas::scheduler::batched_handler(Arc::new(|p: &Json, _| Ok(p.clone()))),
    );
    let mk = |name: &str, class: &str| {
        Json::obj(vec![("patch", Json::str(name)), ("class", Json::str(class))])
    };
    let payloads = vec![
        mk("a0", "A"),
        mk("b0", "B"),
        mk("a0", "A"), // duplicate
        mk("a1", "A"),
        mk("b1", "B"),
    ];
    let sub = client.run_coalesced_routed(&payloads, f, 2).unwrap();
    assert_eq!(sub.tasks.len(), 2); // A-batch (a0, a1) + B-batch (b0, b1)
    let group_results = client
        .gather(&sub.tasks, Duration::from_secs(10), Duration::from_millis(1), None, |_, _| {})
        .unwrap();
    let results = sub.unpack(&group_results).unwrap();
    assert_eq!(results.len(), 5);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.as_ref().unwrap(), &payloads[i]);
    }
    let m = svc.metrics.snapshot();
    assert_eq!(m.routed, 2, "each coalesced group is routed once");
    assert_eq!(m.dedup_hits, 1);
    ep0.shutdown();
    ep1.shutdown();
}

#[test]
fn gather_timeout_cancels_and_drains_outstanding_tasks() {
    // regression: gather used to return Err on timeout and walk away —
    // outstanding tasks kept running, occupied workers, and their results
    // leaked in the service store forever
    let svc = Service::new();
    let executions = Arc::new(AtomicUsize::new(0));
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("slow").with_executor(single_worker_exec()),
    );
    let f = {
        let executions = executions.clone();
        svc.register_function(
            "slow",
            Arc::new(move |p: &Json, _: &mut _| {
                executions.fetch_add(1, Ordering::SeqCst);
                // long per-task sleep vs the 100 ms gather deadline below:
                // even a badly descheduled CI runner cannot finish all six
                // before the timeout fires
                std::thread::sleep(Duration::from_millis(200));
                Ok(p.clone())
            }),
        )
    };
    let client = FaasClient::new(svc.clone());
    let tasks = client
        .run_batch((0..6).map(|i| Json::num(i as f64)).collect(), ep.id, f)
        .unwrap();

    let collected = Arc::new(Mutex::new(Vec::new()));
    let err = {
        let collected = collected.clone();
        client
            .gather(
                &tasks,
                Duration::from_millis(100),
                Duration::from_millis(2),
                None,
                move |i, _| collected.lock().unwrap().push(i),
            )
            .unwrap_err()
    };
    assert!(err.contains("cancelled"), "error must report the cleanup: {err}");
    assert!(svc.metrics.snapshot().cancelled >= 1);

    // every uncollected task must vanish from the store: cancelled pending
    // tasks immediately, the abandoned in-flight one when its handler
    // returns
    let collected = collected.lock().unwrap().clone();
    let outstanding: Vec<_> = (0..tasks.len()).filter(|i| !collected.contains(i)).collect();
    assert!(!outstanding.is_empty(), "test needs a timeout with work left");
    let t0 = std::time::Instant::now();
    loop {
        let leaked = outstanding.iter().filter(|&&i| svc.task_state(tasks[i]).is_some()).count();
        if leaked == 0 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "{leaked} task records leaked");
        std::thread::sleep(Duration::from_millis(5));
    }
    // cancelled queued tasks never reached a worker
    assert!(
        executions.load(Ordering::SeqCst) < 6,
        "cancelled tasks still executed: {}",
        executions.load(Ordering::SeqCst)
    );
    ep.shutdown();
}

#[test]
fn batcher_dedup_survives_forced_hash_collisions() {
    // regression (e2e view of the batcher fix): colliding-but-distinct
    // payloads stay individually submitted, true duplicates still dedup
    let mk = |name: &str| {
        Json::obj(vec![("patch", Json::str(name)), ("class", Json::str("A"))])
    };
    let payloads = vec![mk("p1"), mk("p2"), mk("p1")];
    let plan = pyhf_faas::scheduler::plan_batches_hashed(&payloads, 8, |_| 7);
    assert_eq!(plan.dedup_hits, 1, "true duplicate must dedup through the collision");
    assert_eq!(plan.canonical, vec![0, 1, 0]);
    assert_eq!(plan.groups.iter().map(|g| g.len()).sum::<usize>(), 2);
}

#[test]
fn affinity_queue_age_is_true_minimum() {
    // regression (e2e view of the aging fix): the autoscaler's latency
    // signal must see the oldest task even when stamps arrive out of order
    use pyhf_faas::scheduler::{AffinityPolicy, SchedPolicy, TaskMeta};
    let mut p = AffinityPolicy::new();
    let old = std::time::Instant::now()
        .checked_sub(Duration::from_secs(3))
        .expect("3 s into the past");
    p.push(TaskMeta::bare(1));
    p.push(TaskMeta { enqueued: old, ..TaskMeta::bare(2) });
    let reported = p.oldest_enqueued().expect("non-empty queue");
    assert_eq!(reported, old, "queue age under-reported");
}

#[test]
fn sim_affinity_beats_fifo_on_table1_workload() {
    // the acceptance check behind benches/scheduler.rs, in test form: on
    // the mixed Table-1 workload over the RIVER topology, warm-worker
    // affinity yields lower mean task latency and fewer cold compiles than
    // the seed FIFO interchange
    let tasks = table1_mixed_workload();
    let topo = Topology::river_table1();
    for seed in [1u64, 42, 0x5c4ed] {
        let fifo = simulate_policy(&tasks, topo, CostModel::river(), 5.0, SimPolicy::Fifo, seed);
        let affinity =
            simulate_policy(&tasks, topo, CostModel::river(), 5.0, SimPolicy::Affinity, seed);
        assert!(
            affinity.mean_latency_s < fifo.mean_latency_s,
            "seed {seed}: affinity {:.2} s !< fifo {:.2} s",
            affinity.mean_latency_s,
            fifo.mean_latency_s
        );
        assert!(
            affinity.compiles < fifo.compiles,
            "seed {seed}: compiles {} !< {}",
            affinity.compiles,
            fifo.compiles
        );
        // both schedules complete the full workload
        assert_eq!(fifo.completions_s.len(), tasks.len());
        assert_eq!(affinity.completions_s.len(), tasks.len());
    }
}

#[test]
fn router_quarantines_endpoint_whose_workers_fail_init() {
    // the fault-aware-routing regression: a site whose workers all die in
    // init (missing artifacts) must be quarantined by the router's health
    // scoring, routed work must land on the healthy survivor, and the
    // quarantine must be visible in the service metrics
    use pyhf_faas::scheduler::HealthConfig;
    let svc = Service::new();
    let sick = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("sick")
            .with_executor(ExecutorConfig {
                max_blocks: 1,
                nodes_per_block: 1,
                workers_per_node: 4,
                parallelism: 1.0,
                poll: Duration::from_millis(1),
            })
            .with_worker_init(Arc::new(|_ctx: &mut _| Err("no artifacts".into()))),
    );
    let healthy = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("healthy").with_executor(single_worker_exec()),
    );
    let f = svc.register_function("echo", Arc::new(|p: &Json, _ctx: &mut _| Ok(p.clone())));
    // long backoff: the broken site must stay out for the whole test (its
    // readmission lifecycle is covered by the router unit tests)
    let mut router = Router::new(RouteStrategyKind::LeastLoaded).with_health_config(
        HealthConfig {
            backoff_base: Duration::from_secs(30),
            backoff_max: Duration::from_secs(30),
            ..Default::default()
        },
    );
    router.add_target(sick.id, 0, sick.probe());
    router.add_target(healthy.id, 1, healthy.probe());
    svc.install_router(router);

    // provoke the init failures: one sacrificial task makes the sick site
    // provision its block, whose four workers all die in init
    let sacrificial = svc.submit(sick.id, f, Json::num(-1.0)).unwrap();
    let t0 = std::time::Instant::now();
    while sick.metrics_snapshot().worker_init_failures < 3
        && t0.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(
        sick.metrics_snapshot().worker_init_failures >= 3,
        "sick endpoint's workers never failed init"
    );

    // routed work now avoids the sick endpoint entirely
    let client = FaasClient::new(svc.clone());
    let ids: Vec<_> =
        (0..6).map(|i| client.run_routed(Json::num(i as f64), f).unwrap()).collect();
    for (i, id) in ids.iter().enumerate() {
        let r = svc.wait_result(*id, Duration::from_secs(10)).unwrap();
        assert_eq!(r.as_f64(), Some(i as f64), "routed task served wrong result");
    }
    let m = svc.metrics.snapshot();
    assert!(m.endpoints_quarantined >= 1, "sick endpoint was never quarantined");
    assert_eq!(
        svc.outstanding(sick.id),
        1,
        "only the sacrificial task may sit on the sick site"
    );
    assert!(svc.cancel(sacrificial), "sacrificial task should still be pending");
    healthy.shutdown();
    sick.shutdown();
}
