//! End-to-end durability properties against the live stack: a journaled
//! scan killed mid-flight resumes on a fresh coordinator with terminal
//! results re-delivered (never re-executed) and only the lost tail
//! resubmitted; the coordinator-kill chaos fault drives the same restart;
//! a crash-looping task is terminated with the typed poison outcome; and
//! the driver-level `--journal` / `--resume` path restores every
//! completed point. The chaos harness is process-global, so every test
//! serializes on one lock (executors consult it on each execution).

use std::collections::HashSet;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use pyhf_faas::coordinator::journal::{self, Journal};
use pyhf_faas::coordinator::reliability::is_poison_task;
use pyhf_faas::coordinator::{
    chaos, run_scan, ChaosFault, ChaosPlan, ChaosRule, Endpoint, EndpointConfig, ExecutorConfig,
    FaasClient, FaultPoint, ReliabilityPolicy, RetryPolicy, ScanOptions, Service, ServiceHandle,
};
use pyhf_faas::util::json::Json;

fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn quick_endpoint(svc: &ServiceHandle, name: &str, workers: usize) -> Endpoint {
    Endpoint::start(
        svc.clone(),
        EndpointConfig::new(name).with_executor(ExecutorConfig {
            max_blocks: 1,
            nodes_per_block: 1,
            workers_per_node: workers,
            parallelism: 1.0,
            poll: Duration::from_millis(1),
        }),
    )
}

fn patch(i: usize) -> Json {
    Json::obj(vec![("patch", Json::str(format!("p{i}"))), ("class", Json::str("A"))])
}

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("pyhf-faas-{tag}-{}.journal", std::process::id()))
}

/// Wait until the service ledger shows at least `want` completions.
fn wait_completed(svc: &ServiceHandle, want: u64) {
    let t0 = Instant::now();
    while svc.metrics.snapshot().completed < want {
        assert!(t0.elapsed() < Duration::from_secs(20), "never reached {want} completions");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// Kill-and-resume: a journaled wave is torn down mid-flight (the journal
/// snapshot taken at the kill instant is byte-for-byte what disk would
/// hold on SIGKILL); a fresh service recovers it, re-delivering the
/// journaled completions without re-executing them and resubmitting the
/// rest, and the ledger invariant holds across the restart.
#[test]
fn kill_and_resume_redelivers_without_reexecution() {
    let _g = chaos_lock();
    chaos::clear();
    let path = tmp("e2e-kill");
    let kill = tmp("e2e-kill-snapshot");
    let n = 12usize;

    let svc = Service::new();
    let ep = quick_endpoint(&svc, "jrn-kill", 2);
    let client = FaasClient::new(svc.clone());
    let f = client.register_function(
        "echo-slow",
        Arc::new(|p: &Json, _: &mut _| {
            std::thread::sleep(Duration::from_millis(20));
            Ok(p.clone())
        }),
    );
    let j = Journal::create(&path).unwrap();
    j.append(journal::Record::Header(journal::scan_header(
        "e2e",
        &journal::hash_hex(journal::content_hash(["e2e"])),
        n,
    )));
    svc.set_journal(Arc::new(j));

    let _tasks: Vec<_> = (0..n).map(|i| client.run(patch(i), ep.id, f).unwrap()).collect();
    wait_completed(&svc, 4);
    // the kill instant: snapshot the journal before the graceful teardown
    // (which drains still-queued tasks as failures) can append anything
    svc.journal_handle().unwrap().sync();
    std::fs::copy(&path, &kill).unwrap();
    ep.shutdown();
    drop(client);
    drop(svc);

    // fresh coordinator: recover the snapshot, resubmitting the tail
    let svc2 = Service::new();
    let ep2 = quick_endpoint(&svc2, "jrn-resume", 2);
    let executed: Arc<Mutex<HashSet<String>>> = Arc::new(Mutex::new(HashSet::new()));
    let client2 = FaasClient::new(svc2.clone());
    let f2 = client2.register_function("echo", {
        let executed = executed.clone();
        Arc::new(move |p: &Json, _: &mut _| {
            let key = p.get("patch").and_then(|v| v.as_str()).unwrap_or("?").to_string();
            executed.lock().unwrap().insert(key);
            Ok(p.clone())
        })
    });
    let (loaded, state) = Journal::load(&kill).unwrap();
    drop(loaded);
    let done_keys: Vec<String> = state.done_by_key().keys().cloned().collect();
    assert!(done_keys.len() >= 4, "setup: too few journaled completions");

    let rec = svc2.recover(&kill, f2, Some(ep2.id), true).unwrap();
    assert_eq!(rec.delivered.len(), done_keys.len());
    assert_eq!(rec.delivered.len() + rec.resubmitted.len(), n);
    assert!(!rec.resubmitted.is_empty(), "the kill left no tail to resubmit");

    // re-delivered results are available immediately, value intact
    for (key, id) in &rec.delivered {
        let v = svc2.try_result(*id).expect("delivered result must be terminal").unwrap();
        assert_eq!(v.get("patch").and_then(|p| p.as_str()), key.as_deref());
    }
    for (_k, id) in &rec.resubmitted {
        svc2.wait_result(*id, Duration::from_secs(10)).expect("resubmitted fit");
    }
    svc2.journal_handle().unwrap().sync();
    ep2.shutdown();

    // never double-executed: no journaled completion ran on the new stack;
    // the resubmitted tail all did
    let ex = executed.lock().unwrap();
    for k in &done_keys {
        assert!(!ex.contains(k), "journaled completion '{k}' was re-executed");
    }
    for (k, _) in &rec.resubmitted {
        assert!(ex.contains(k.as_deref().unwrap()), "tail task {k:?} never ran");
    }
    drop(ex);

    let m = svc2.metrics.snapshot();
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled, "ledger across restart");
    assert_eq!(m.recovered_delivered, rec.delivered.len() as u64);
    assert_eq!(m.recovered_resubmitted, rec.resubmitted.len() as u64);
    assert!(m.journal_appends > 0, "the successor journal never saw an append");

    // the promoted successor journal is consistent: every point terminal
    let (l2, s2) = Journal::load(&kill).unwrap();
    drop(l2);
    assert_eq!(s2.done_by_key().len(), n);
    assert!(s2.open.is_empty(), "promoted journal still has open tasks: {:?}", s2.open);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&kill);
}

/// The coordinator-kill chaos fault decides the kill point: the rule is
/// consulted at the `Coordinator` fault point once per observed
/// completion, fires exactly once, and the restart it forces reconciles.
#[test]
fn coordinator_kill_chaos_rule_drives_restart() {
    let _g = chaos_lock();
    chaos::clear();
    let path = tmp("e2e-chaos-kill");
    let kill = tmp("e2e-chaos-kill-snapshot");
    let n = 16usize;

    let svc = Service::new();
    let ep = quick_endpoint(&svc, "jrn-chaos", 2);
    let client = FaasClient::new(svc.clone());
    let f = client.register_function(
        "echo-slow",
        Arc::new(|p: &Json, _: &mut _| {
            std::thread::sleep(Duration::from_millis(25));
            Ok(p.clone())
        }),
    );
    let j = Journal::create(&path).unwrap();
    j.append(journal::Record::Header(journal::scan_header(
        "e2e-chaos",
        &journal::hash_hex(journal::content_hash(["e2e-chaos"])),
        n,
    )));
    svc.set_journal(Arc::new(j));
    chaos::install(
        ChaosPlan::new(0xc0de).rule(ChaosRule::new(ChaosFault::KillCoordinator, None, 5, 1)),
    );

    let _tasks: Vec<_> = (0..n).map(|i| client.run(patch(i), ep.id, f).unwrap()).collect();
    // consult the Coordinator fault point once per completion; the rule
    // firing means "the coordinator dies here"
    let t0 = Instant::now();
    let mut consulted = 0u64;
    let killed = 'kill: loop {
        assert!(t0.elapsed() < Duration::from_secs(20), "kill rule never fired");
        let completed = svc.metrics.snapshot().completed;
        while consulted < completed {
            consulted += 1;
            if matches!(
                chaos::inject(FaultPoint::Coordinator, ep.id, None),
                Some(ChaosFault::KillCoordinator)
            ) {
                break 'kill true;
            }
        }
        if completed >= n as u64 {
            break false;
        }
        std::thread::sleep(Duration::from_millis(1));
    };
    let plan = chaos::clear().expect("chaos plan was installed");
    assert!(killed, "workload finished before the KillCoordinator rule fired");
    assert_eq!(plan.total_hits(), 1, "KillCoordinator must fire exactly once");
    svc.journal_handle().unwrap().sync();
    std::fs::copy(&path, &kill).unwrap();
    ep.shutdown();
    drop(client);
    drop(svc);

    let svc2 = Service::new();
    let ep2 = quick_endpoint(&svc2, "jrn-chaos-resume", 2);
    let client2 = FaasClient::new(svc2.clone());
    let f2 = client2.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));
    let rec = svc2.recover(&kill, f2, Some(ep2.id), true).unwrap();
    assert!(rec.delivered.len() >= 5, "the rule fired after 5 journaled completions");
    assert_eq!(rec.delivered.len() + rec.resubmitted.len(), n);
    for (_k, id) in &rec.resubmitted {
        svc2.wait_result(*id, Duration::from_secs(10)).expect("resubmitted fit");
    }
    ep2.shutdown();
    let m = svc2.metrics.snapshot();
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled, "ledger across restart");
    assert_eq!(m.completed, n as u64);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&kill);
}

/// Poison-task termination: a fit whose every attempt crashes its worker
/// is terminated with the typed `POISON_TASK` outcome after
/// `max_total_attempts` crash-attributed attempts, instead of retrying
/// (and killing workers) forever.
#[test]
fn poison_task_terminates_crash_looping_fit() {
    let _g = chaos_lock();
    chaos::clear();

    let svc = Service::new();
    let ep = quick_endpoint(&svc, "jrn-poison", 4);
    let client = FaasClient::new(svc.clone()).with_reliability(
        ReliabilityPolicy::new()
            .with_retry(RetryPolicy {
                max_attempts: 5,
                backoff_base: Duration::from_millis(2),
                ..Default::default()
            })
            .with_max_total_attempts(2),
    );
    let f = client.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));

    // every execution of the task takes its worker down with it
    chaos::install(ChaosPlan::new(0x0bad).rule(ChaosRule::new(ChaosFault::Crash, Some(ep.id), 0, 8)));
    let t = client.run(patch(0), ep.id, f).unwrap();
    let results = client
        .gather(&[t], Duration::from_secs(20), Duration::from_millis(2), None, |_, _| {})
        .expect("gather");
    let plan = chaos::clear().expect("plan still installed");
    ep.shutdown();

    assert_eq!(plan.total_hits(), 2, "two crash-attributed attempts before the verdict");
    let err = results[0].as_ref().expect_err("a poison task must fail");
    assert!(is_poison_task(err), "untyped poison outcome: {err}");
    let m = svc.metrics.snapshot();
    assert_eq!(m.poisoned, 1);
    assert_eq!(m.retries, 1, "exactly one resubmission before termination");
    assert_eq!(m.completed, 0);
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
}

/// Driver-level `--journal` then `--resume`: a completed journaled scan
/// resumed on a fresh stack restores every point from the journal and
/// refits nothing, reproducing the same physics.
#[test]
fn scan_journal_then_resume_restores_every_point() {
    let _g = chaos_lock();
    chaos::clear();
    let jp = tmp("scan-resume");
    let dir = std::env::temp_dir().join(format!("jrn-scan-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), TEST_MANIFEST).unwrap();
    let pallet = pyhf_faas::pallet::generate(&pyhf_faas::pallet::library::config_quickstart());

    let native_endpoint = |svc: &ServiceHandle, name: &str| {
        Endpoint::start(
            svc.clone(),
            EndpointConfig::new(name)
                .with_executor(ExecutorConfig {
                    max_blocks: 1,
                    nodes_per_block: 1,
                    workers_per_node: 2,
                    parallelism: 1.0,
                    poll: Duration::from_millis(1),
                })
                .with_worker_init(pyhf_faas::coordinator::fitops::native_worker_init(dir.clone())),
        )
    };

    let svc = Service::new();
    let ep = native_endpoint(&svc, "jrn-scan");
    let client = FaasClient::new(svc.clone());
    let f = client
        .register_function("fit_patch_native", pyhf_faas::coordinator::fitops::native_fit_handler());
    let opts =
        ScanOptions { limit: Some(4), journal: Some(jp.clone()), ..Default::default() };
    let scan1 = run_scan(&client, ep.id, f, &pallet, &opts).unwrap();
    assert_eq!(scan1.points.len(), 4);
    assert!(svc.journal_enabled());
    assert!(svc.metrics.snapshot().journal_appends > 0);
    ep.shutdown();
    drop(client);
    drop(svc);

    let svc2 = Service::new();
    let ep2 = native_endpoint(&svc2, "jrn-scan-resume");
    let client2 = FaasClient::new(svc2.clone());
    let f2 = client2
        .register_function("fit_patch_native", pyhf_faas::coordinator::fitops::native_fit_handler());
    let opts =
        ScanOptions { limit: Some(4), resume: Some(jp.clone()), ..Default::default() };
    let scan2 = run_scan(&client2, ep2.id, f2, &pallet, &opts).unwrap();
    ep2.shutdown();

    assert_eq!(scan2.points.len(), 4);
    let m = svc2.metrics.snapshot();
    assert_eq!(m.recovered_delivered, 4, "every point restored from the journal");
    assert_eq!(m.completed, 4);
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
    for (a, b) in scan1.points.iter().zip(&scan2.points) {
        assert_eq!(a.patch, b.patch);
        assert!((a.cls_obs - b.cls_obs).abs() < 1e-12, "restored physics drifted");
    }
    let _ = std::fs::remove_file(&jp);
    let _ = std::fs::remove_dir_all(&dir);
}

const TEST_MANIFEST: &str = r#"{
    "format": "hlo-text", "dtype": "f64", "mu_test": 1.0, "use_pallas": true,
    "input_order": [], "output_order": [],
    "entries": {
        "hypotest_quickstart": {
            "file": "hypotest_quickstart.hlo.txt", "kind": "hypotest",
            "shape_class": {"name": "quickstart", "n_bins": 16, "n_samples": 6,
                            "n_alpha": 6, "n_free": 2, "bin_block": 16,
                            "mu_max": 10.0, "max_newton": 32, "cg_iters": 24},
            "inputs": []
        }
    }
}"#;
