#![cfg(feature = "loom")]
//! Exhaustive interleaving models of the fabric's two racy protocols,
//! gated behind `--features loom` (CI job `analysis`).
//!
//! No external model-checking dependency: the explorer below enumerates
//! *every* interleaving of the per-thread operation sequences and replays
//! each schedule against fresh state. The queue's critical sections are
//! single-lock, so its public calls are the linearization points —
//! enumerating call-level interleavings covers every distinguishable
//! behavior, the same reduction loom applies to lock-protected state.
//!
//! * `interchange_*` drive the REAL [`SchedQueue`] through all schedules
//!   of submit/claim/cancel/close/drain, checking the weight/len ledger
//!   after every step and exactly-one-disposition at the end.
//! * `hedge_*` model the client's hedge-vs-result race (mirroring
//!   `FaasClient::poll_slot`: hedge harvested first, slot leaves the
//!   pending set on its first terminal outcome) and assert exactly one
//!   terminal outcome under every arrival order.

use std::collections::HashMap;
use std::time::Duration;

use pyhf_faas::scheduler::policy::TaskMeta;
use pyhf_faas::scheduler::queue::SchedQueue;

/// All interleavings of threads with `counts[t]` sequential ops each:
/// every sequence over thread ids preserving per-thread program order.
fn schedules(counts: &[usize]) -> Vec<Vec<usize>> {
    fn go(remaining: &mut Vec<usize>, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if remaining.iter().all(|&r| r == 0) {
            out.push(cur.clone());
            return;
        }
        for t in 0..remaining.len() {
            if remaining[t] > 0 {
                remaining[t] -= 1;
                cur.push(t);
                go(remaining, cur, out);
                cur.pop();
                remaining[t] += 1;
            }
        }
    }
    let mut out = Vec::new();
    go(&mut counts.to_vec(), &mut Vec::new(), &mut out);
    out
}

#[test]
fn schedule_enumeration_is_multinomial() {
    // 4! / (2! * 2!) = 6
    assert_eq!(schedules(&[2, 2]).len(), 6);
    // 7! / (2! * 2! * 1! * 2!) = 630
    assert_eq!(schedules(&[2, 2, 1, 2]).len(), 630);
}

// ---------------------------------------------------------------------------
// model: the SchedQueue interchange
// ---------------------------------------------------------------------------

/// Ledger mirroring what the queue *should* hold, updated from each op's
/// observable return value.
#[derive(Default)]
struct Ledger {
    weights: HashMap<u64, usize>,
    accepted: Vec<u64>,
    popped: Vec<u64>,
    discarded: Vec<u64>,
    drained: Vec<u64>,
}

impl Ledger {
    fn queued(&self) -> Vec<u64> {
        self.accepted
            .iter()
            .copied()
            .filter(|id| {
                !self.popped.contains(id)
                    && !self.discarded.contains(id)
                    && !self.drained.contains(id)
            })
            .collect()
    }

    fn check(&self, q: &SchedQueue, step: &str, sched: &[usize]) {
        let queued = self.queued();
        let weight: usize = queued.iter().map(|id| self.weights[id].max(1)).sum();
        assert_eq!(q.len(), queued.len(), "len after {step} in {sched:?}");
        assert_eq!(q.queued_weight(), weight, "weight after {step} in {sched:?}");
    }
}

/// Thread programs: producer pushes two tasks, a worker claims twice, a
/// client cancels task 1, shutdown closes then drains. 630 schedules.
#[test]
fn interchange_every_schedule_reconciles() {
    for sched in schedules(&[2, 2, 1, 2]) {
        let q = SchedQueue::new();
        let mut led = Ledger::default();
        led.weights.insert(1, 2);
        led.weights.insert(2, 1);
        let mut pc = [0usize; 4];
        for &t in &sched {
            let step = match (t, pc[t]) {
                (0, 0) => {
                    if q.push_meta(TaskMeta { weight: 2, ..TaskMeta::bare(1) }) {
                        led.accepted.push(1);
                    }
                    "push(1)"
                }
                (0, 1) => {
                    if q.push_meta(TaskMeta::bare(2)) {
                        led.accepted.push(2);
                    }
                    "push(2)"
                }
                (1, _) => {
                    if let Some(id) = q.pop(Duration::ZERO) {
                        led.popped.push(id);
                    }
                    "pop"
                }
                (2, 0) => {
                    if q.discard(1) {
                        led.discarded.push(1);
                    }
                    "discard(1)"
                }
                (3, 0) => {
                    q.close();
                    "close"
                }
                (3, 1) => {
                    for m in q.drain_remaining() {
                        led.drained.push(m.id);
                    }
                    "drain"
                }
                other => panic!("no op for {other:?}"),
            };
            pc[t] += 1;
            led.check(&q, step, &sched);
        }
        // terminal: whatever is still queued drains; afterwards every
        // accepted task has exactly one disposition and the ledger
        // reconciles — accepted == popped + discarded + drained
        let leftover: Vec<u64> = q.drain_remaining().into_iter().map(|m| m.id).collect();
        assert_eq!(q.queued_weight(), 0, "{sched:?}");
        assert_eq!(q.len(), 0, "{sched:?}");
        for id in &led.accepted {
            let n = [&led.popped, &led.discarded, &led.drained, &leftover]
                .iter()
                .map(|v| v.iter().filter(|x| *x == id).count())
                .sum::<usize>();
            assert_eq!(n, 1, "task {id} dispositions in {sched:?}");
        }
        assert_eq!(
            led.accepted.len(),
            led.popped.len() + led.discarded.len() + led.drained.len() + leftover.len(),
            "{sched:?}"
        );
    }
}

/// The push-vs-close race in isolation: an accepted push must be visible
/// to the shutdown drain (or a pop); a rejected push must leave no trace.
/// No schedule may strand an accepted task or resurrect a rejected one.
#[test]
fn interchange_close_race_never_strands_a_task() {
    for sched in schedules(&[1, 2]) {
        let q = SchedQueue::new();
        let mut accepted = false;
        let mut seen = 0usize;
        let mut pc = [0usize; 2];
        for &t in &sched {
            match (t, pc[t]) {
                (0, 0) => accepted = q.push_meta(TaskMeta::bare(7)),
                (1, 0) => q.close(),
                (1, 1) => seen += q.drain_remaining().len(),
                other => panic!("no op for {other:?}"),
            }
            pc[t] += 1;
        }
        seen += q.drain_remaining().len();
        assert_eq!(seen, usize::from(accepted), "{sched:?}");
        assert!(q.pop(Duration::ZERO).is_none(), "{sched:?}");
    }
}

// ---------------------------------------------------------------------------
// model: the hedge-vs-result race (mirrors client.rs poll_slot)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq)]
enum Outcome {
    HedgeWon,
    PrimaryWon,
    Failed,
}

/// Result mailboxes: a harvest consumes the cell, like
/// `FaasClient::get_result` taking a completed task out of the store.
#[derive(Default)]
struct World {
    primary: Option<Result<(), ()>>,
    hedge: Option<Result<(), ()>>,
}

struct SlotModel {
    hedge_outstanding: bool,
    primary_cancelled: bool,
    hedge_cancelled: bool,
    finalized: Option<Outcome>,
}

impl SlotModel {
    fn new() -> SlotModel {
        SlotModel {
            hedge_outstanding: true,
            primary_cancelled: false,
            hedge_cancelled: false,
            finalized: None,
        }
    }

    /// One gather sweep over this slot — the transition rules of
    /// `poll_slot`: hedge harvested first (a winning hedge cancels the
    /// primary; a failed hedge is dropped and never fails the logical
    /// task), then the primary (beating its hedge abandons the duplicate).
    fn poll(&mut self, w: &mut World) {
        if self.finalized.is_some() {
            // a finalized slot has left the pending set; gather never
            // polls it again — modelled as a hard error instead
            panic!("poll after terminal outcome");
        }
        if self.hedge_outstanding {
            match w.hedge.take() {
                Some(Ok(())) => {
                    self.primary_cancelled = true;
                    self.hedge_outstanding = false;
                    self.set_final(Outcome::HedgeWon);
                    return;
                }
                Some(Err(())) => {
                    self.hedge_outstanding = false;
                    self.hedge_cancelled = true;
                }
                None => {}
            }
        }
        if let Some(r) = w.primary.take() {
            if self.hedge_outstanding {
                self.hedge_outstanding = false;
                self.hedge_cancelled = true;
            }
            self.set_final(match r {
                Ok(()) => Outcome::PrimaryWon,
                Err(()) => Outcome::Failed,
            });
        }
    }

    fn set_final(&mut self, o: Outcome) {
        // THE invariant: exactly one terminal outcome per logical task
        assert!(self.finalized.is_none(), "double finalization: {:?} then {o:?}", self.finalized);
        self.finalized = Some(o);
    }
}

/// Every arrival order × every poll placement × all four result combos:
/// the slot finalizes exactly once, and the losing attempt is always
/// cancelled (no orphaned duplicate).
#[test]
fn hedge_race_exactly_one_terminal_outcome() {
    let combos: [(Result<(), ()>, Result<(), ()>); 4] =
        [(Ok(()), Ok(())), (Ok(()), Err(())), (Err(()), Ok(())), (Err(()), Err(()))];
    for (pres, hres) in combos {
        for sched in schedules(&[1, 1, 3]) {
            let mut w = World::default();
            let mut s = SlotModel::new();
            for &t in &sched {
                match t {
                    0 => w.primary = Some(pres),
                    1 => w.hedge = Some(hres),
                    2 => {
                        if s.finalized.is_none() {
                            s.poll(&mut w);
                        }
                    }
                    other => panic!("no thread {other}"),
                }
            }
            // results may arrive after the last in-schedule sweep; gather
            // keeps sweeping until the slot finalizes
            for _ in 0..2 {
                if s.finalized.is_none() {
                    s.poll(&mut w);
                }
            }
            let f = s.finalized.unwrap_or_else(|| {
                panic!("slot never finalized under {sched:?} with {pres:?}/{hres:?}")
            });
            match f {
                Outcome::HedgeWon => {
                    assert_eq!(hres, Ok(()), "{sched:?}");
                    assert!(s.primary_cancelled, "straggler must be cancelled: {sched:?}");
                }
                Outcome::PrimaryWon => assert_eq!(pres, Ok(()), "{sched:?}"),
                Outcome::Failed => assert_eq!(pres, Err(()), "{sched:?}"),
            }
            // a failed hedge never fails the logical task
            if hres == Err(()) {
                assert_ne!(f, Outcome::HedgeWon, "{sched:?}");
            }
            // no orphaned duplicate: every terminal path either crowned
            // the hedge or cancelled it — it is never left outstanding
            assert!(!s.hedge_outstanding, "orphaned hedge: {sched:?}");
            assert!(
                f == Outcome::HedgeWon || s.hedge_cancelled,
                "losing hedge must be cancelled: {sched:?}"
            );
        }
    }
}
