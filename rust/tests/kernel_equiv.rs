//! Differential harness for the SIMD microkernel tiers (ISSUE 10).
//!
//! Generates hundreds of seeded random model shapes — varying bin counts
//! (including every lane-remainder size), sample counts, modifier mixes,
//! padding, denormal-adjacent and large-count bins — and proves every
//! tier the CPU can run equivalent to the scalar reference and to the
//! preserved seed implementation (`fitter::baseline`):
//!
//! * NLL: **bitwise identical** across tiers (the sweep is element-wise
//!   with fused-multiply-add semantics in every tier), and within a
//!   relative 1e-6 of the seed fitter (which counts an extra clipped
//!   `EPS_RATE` per padded row);
//! * gradient / Fisher: within an ULP-scale budget of the scalar tier
//!   (reduction order differs per lane width) and a relative 1e-6 of the
//!   seed on non-fixed parameters;
//! * the batched multi-patch sweep: **bitwise equal** to evaluating each
//!   patch sequentially.
//!
//! Own test binary: the tier selection is process-global, so forcing
//! tiers here must not race the other test targets (see Cargo.toml).

use std::collections::BTreeSet;
use std::sync::{Mutex, MutexGuard, OnceLock};

use pyhf_faas::fitter::simd::{self, batch, Tier};
use pyhf_faas::fitter::{nll_batch, BaselineFitter, Centers, FitScratch, NativeFitter, NllBatch};
use pyhf_faas::histfactory::dense::{compile, DenseModel, ShapeClass};
use pyhf_faas::histfactory::spec::Workspace;
use pyhf_faas::util::json::Json;
use pyhf_faas::util::rng::Rng;

/// The tier selection is one process-global atomic; every test that forces
/// tiers serializes on this lock and restores the initial tier on exit.
fn tier_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs() + b.abs())
}

// ---------------------------------------------------------------------------
// seeded shape generator
// ---------------------------------------------------------------------------

/// Bin-content scale families: ordinary, large-count (~1e6 per bin, the
/// paper's control-region regime) and sub-clip (below `EPS_RATE`, which
/// exercises the rate-clipping mask in every lane).
fn pick_scale(r: &mut Rng) -> f64 {
    match r.below(8) {
        0 => 1e4,
        1 => 1e-12,
        _ => 1.0,
    }
}

/// One random single-channel workspace plus a (possibly padded) shape
/// class it compiles into. Bin counts sweep 1..=2*max_lanes and beyond so
/// every tier sees full tiles, lane remainders and sub-lane-width models.
fn gen_shape(r: &mut Rng) -> (Workspace, ShapeClass) {
    let nb = match r.below(10) {
        0 => 1,
        1 => 1 + r.below(8),      // 1..=8: every remainder of 2- and 4-lane tiles
        2 => 4 * (1 + r.below(3)) + 1, // 5, 9, 13: exactly one lane past a tile
        3 => 16 + r.below(9),     // 16..=24
        _ => 2 + r.below(7),      // 2..=8
    };
    let scale = pick_scale(r);
    let n_bkg = 1 + r.below(3);

    let fvec = |v: &[f64]| Json::arr_f64(v);
    let sig: Vec<f64> = (0..nb).map(|_| r.uniform(0.1, 8.0) * scale).collect();
    let mut samples = vec![Json::obj(vec![
        ("name", Json::str("signal")),
        ("data", fvec(&sig)),
        (
            "modifiers",
            Json::Arr(vec![Json::obj(vec![
                ("name", Json::str("mu")),
                ("type", Json::str("normfactor")),
                ("data", Json::Null),
            ])]),
        ),
    ])];

    let mut alpha_names: BTreeSet<String> = BTreeSet::new();
    let mut bkg_total = vec![0.0; nb];
    for j in 0..n_bkg {
        // occasionally an all-zero row: its rates clip to EPS_RATE in
        // every bin, so the whole row is "masked" by the clip gate
        let zero_row = r.below(12) == 0 && n_bkg > 1;
        let bkg: Vec<f64> = (0..nb)
            .map(|_| if zero_row { 0.0 } else { r.uniform(20.0, 90.0) * scale })
            .collect();
        for (t, b) in bkg_total.iter_mut().zip(&bkg) {
            *t += b;
        }
        let mut modifiers = Vec::new();
        if !zero_row && r.below(4) != 0 {
            // 50/50 a sample-private or a cross-sample-shared normsys
            let name =
                if r.below(2) == 0 { "ns_shared".to_string() } else { format!("ns{j}") };
            alpha_names.insert(name.clone());
            modifiers.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("type", Json::str("normsys")),
                (
                    "data",
                    Json::obj(vec![
                        ("hi", Json::num(1.0 + r.uniform(0.02, 0.25))),
                        ("lo", Json::num(1.0 - r.uniform(0.02, 0.25))),
                    ]),
                ),
            ]));
        }
        if !zero_row && r.below(2) == 0 {
            let name = format!("hs{j}");
            alpha_names.insert(name.clone());
            let hi: Vec<f64> = bkg.iter().map(|b| b * (1.0 + r.uniform(0.01, 0.15))).collect();
            let lo: Vec<f64> = bkg.iter().map(|b| b * (1.0 - r.uniform(0.01, 0.15))).collect();
            modifiers.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("type", Json::str("histosys")),
                (
                    "data",
                    Json::obj(vec![("hi_data", fvec(&hi)), ("lo_data", fvec(&lo))]),
                ),
            ]));
        }
        if !zero_row && r.below(5) < 2 {
            let st: Vec<f64> =
                bkg.iter().map(|b| (b * r.uniform(0.02, 0.08)).max(0.3 * scale)).collect();
            modifiers.push(Json::obj(vec![
                ("name", Json::str("st")),
                ("type", Json::str("staterror")),
                ("data", fvec(&st)),
            ]));
        }
        samples.push(Json::obj(vec![
            ("name", Json::str(format!("bkg{j}"))),
            ("data", fvec(&bkg)),
            ("modifiers", Json::Arr(modifiers)),
        ]));
    }

    let obs: Vec<f64> =
        bkg_total.iter().map(|b| (b + r.uniform(-4.0, 8.0) * scale).max(0.0).round()).collect();
    let doc = Json::obj(vec![
        (
            "channels",
            Json::Arr(vec![Json::obj(vec![
                ("name", Json::str("SR")),
                ("samples", Json::Arr(samples)),
            ])]),
        ),
        (
            "observations",
            Json::Arr(vec![Json::obj(vec![("name", Json::str("SR")), ("data", fvec(&obs))])]),
        ),
        (
            "measurements",
            Json::Arr(vec![Json::obj(vec![
                ("name", Json::str("m")),
                (
                    "config",
                    Json::obj(vec![("poi", Json::str("mu")), ("parameters", Json::Arr(vec![]))]),
                ),
            ])]),
        ),
        ("version", Json::str("1.0.0")),
    ]);
    let ws = Workspace::from_json(&doc).expect("generated workspace parses");

    let class = ShapeClass {
        name: "equiv".into(),
        n_bins: nb + 3 * r.below(3),
        n_samples: (1 + n_bkg) + r.below(3),
        n_alpha: alpha_names.len() + r.below(3),
        n_free: 1 + r.below(2),
        bin_block: [4, 8, 16][r.below(3)],
        mu_max: 10.0,
        max_newton: 48,
        cg_iters: 24,
    };
    (ws, class)
}

/// Random evaluation point: off-nominal mu, alphas and gammas.
fn rand_theta(r: &mut Rng, m: &DenseModel, fitter: &NativeFitter) -> Vec<f64> {
    let (f_, a_) = (m.class.n_free, m.class.n_alpha);
    let mut th = fitter.init_theta(r.uniform(0.2, 3.0));
    for a in 0..m.n_active_alpha {
        th[f_ + a] = r.uniform(-1.8, 1.8);
    }
    for b in 0..m.n_active_bins {
        if m.ctype[b] > 0.0 {
            th[f_ + a_ + b] = r.uniform(0.92, 1.08);
        }
    }
    th
}

/// The core differential check for one compiled shape: every supported
/// tier against the scalar reference (NLL bitwise; grad/Fisher within an
/// ULP-scale budget) and against the seed fitter (relative 1e-6).
fn check_shape(tag: &str, m: &DenseModel, theta: &[f64]) {
    let fused = NativeFitter::new(m);
    let seed = BaselineFitter::new(m);
    let centers = Centers::nominal(m);
    let fixed = fused.fixed_mask(false);
    let p_ = m.class.n_params();

    simd::force(Tier::Scalar).unwrap();
    let nll_ref = fused.nll(theta, &m.data, &centers);
    let (grad_ref, fisher_ref) = fused.grad_fisher(theta, &m.data, &centers, &fixed);

    let nll_seed = seed.nll(theta, &m.data, &centers);
    assert!(
        close(nll_ref, nll_seed, 1e-6),
        "{tag}: scalar nll {nll_ref} != seed nll {nll_seed}"
    );
    let (grad_seed, fisher_seed) = seed.grad_fisher(theta, &m.data, &centers, &fixed);

    for t in simd::supported_tiers() {
        simd::force(t).unwrap();
        let nll_t = fused.nll(theta, &m.data, &centers);
        assert_eq!(
            nll_t.to_bits(),
            nll_ref.to_bits(),
            "{tag}: tier {} nll {nll_t} not bitwise-equal to scalar {nll_ref}",
            t.name()
        );
        let (grad_t, fisher_t) = fused.grad_fisher(theta, &m.data, &centers, &fixed);
        for p in 0..p_ {
            assert!(
                close(grad_t[p], grad_ref[p], 5e-9),
                "{tag}: tier {} grad[{p}] {} vs scalar {}",
                t.name(),
                grad_t[p],
                grad_ref[p]
            );
            if !fixed[p] {
                assert!(
                    close(grad_t[p], grad_seed[p], 1e-6),
                    "{tag}: tier {} grad[{p}] {} vs seed {}",
                    t.name(),
                    grad_t[p],
                    grad_seed[p]
                );
            }
        }
        for i in 0..p_ {
            for j in 0..p_ {
                let (a, b) = (fisher_t[i * p_ + j], fisher_ref[i * p_ + j]);
                assert!(
                    close(a, b, 5e-9),
                    "{tag}: tier {} fisher[{i},{j}] {a} vs scalar {b}",
                    t.name()
                );
                if !fixed[i] && !fixed[j] {
                    let s = fisher_seed[i * p_ + j];
                    assert!(
                        close(a, s, 1e-6),
                        "{tag}: tier {} fisher[{i},{j}] {a} vs seed {s}",
                        t.name()
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// the harness
// ---------------------------------------------------------------------------

#[test]
fn differential_harness_over_500_random_shapes() {
    let _g = tier_lock();
    let initial = simd::active();
    let mut r = Rng::new(0x5eed_51dd);
    for i in 0..520 {
        let (ws, class) = gen_shape(&mut r);
        let m = compile(&ws, &class).unwrap_or_else(|e| panic!("shape {i}: {e}"));
        let fitter = NativeFitter::new(&m);
        let theta = rand_theta(&mut r, &m, &fitter);
        check_shape(&format!("shape {i}"), &m, &theta);
    }
    simd::force(initial).unwrap();
}

/// Mandatory edge shapes: models narrower than a vector register, models
/// one bin past a full tile, fully clip-masked rows, gamma-free models,
/// heavy padding, sub-clip ("denormal-adjacent") and large-count bins.
#[test]
fn edge_shapes_lane_remainders_and_masked_regions() {
    let _g = tier_lock();
    let initial = simd::active();

    // lane-remainder sweep: 1..=9 covers < LANES, == LANES and == 1 (mod
    // LANES) for both 2- and 4-lane tiers
    let mut r = Rng::new(7);
    for nb in 1..=9usize {
        let ws = edge_ws(nb, 1.0, true);
        let class = exact_class(nb, 3, 2, 1);
        let m = compile(&ws, &class).unwrap();
        let fitter = NativeFitter::new(&m);
        let theta = rand_theta(&mut r, &m, &fitter);
        check_shape(&format!("edge nb={nb}"), &m, &theta);
    }

    // all-masked gamma region: no staterror anywhere, so the gamma block
    // of the arrowhead solve is empty and the constraint sweep sees only
    // inactive slots
    let ws = edge_ws(6, 1.0, false);
    let class = exact_class(6, 3, 2, 1);
    let m = compile(&ws, &class).unwrap();
    let fitter = NativeFitter::new(&m);
    let theta = rand_theta(&mut r, &m, &fitter);
    check_shape("edge no-gamma", &m, &theta);
    // the gamma-free model still fits end to end on every tier, through
    // the degenerate (dense-only) arrowhead solve
    for t in simd::supported_tiers() {
        simd::force(t).unwrap();
        let centers = Centers::nominal(&m);
        let fit = fitter.fit_free(&m.data, &centers);
        assert!(
            fit.nll.is_finite() && fit.accepted_steps > 0,
            "no-gamma fit must make progress on tier {}",
            t.name()
        );
    }

    // heavy padding: the same tiny model inside a much larger class —
    // masked tails beyond every active region in every lane width
    let ws = edge_ws(3, 1.0, true);
    let m = compile(&ws, &exact_class(3, 3, 2, 1)).unwrap();
    let mp = compile(&ws, &exact_class(64, 24, 12, 4)).unwrap();
    let fitter = NativeFitter::new(&m);
    let theta = rand_theta(&mut r, &m, &fitter);
    check_shape("edge compact", &m, &theta);
    let fp = NativeFitter::new(&mp);
    let tp = rand_theta(&mut Rng::new(7), &mp, &fp); // irrelevant seed reuse
    check_shape("edge padded", &mp, &tp);

    // sub-clip bins (every raw rate below EPS_RATE: the clip mask kills
    // all lanes) and large-count bins (~1e6 per bin)
    for (label, scale) in [("denormal-adjacent", 1e-12), ("large-count", 1e4)] {
        let ws = edge_ws(5, scale, true);
        let class = exact_class(5, 3, 2, 1);
        let m = compile(&ws, &class).unwrap();
        let fitter = NativeFitter::new(&m);
        let theta = rand_theta(&mut r, &m, &fitter);
        check_shape(&format!("edge {label}"), &m, &theta);
    }

    simd::force(initial).unwrap();
}

/// Deterministic single-channel workspace with `nb` bins: signal with the
/// POI, one modified background (normsys + histosys [+ staterror when
/// `with_gamma`]) and one unmodified background.
fn edge_ws(nb: usize, scale: f64, with_gamma: bool) -> Workspace {
    let sig: Vec<f64> = (0..nb).map(|b| (1.0 + b as f64) * scale).collect();
    let bkg: Vec<f64> = (0..nb).map(|b| (50.0 + 3.0 * b as f64) * scale).collect();
    let flat: Vec<f64> = (0..nb).map(|b| (10.0 + b as f64) * scale).collect();
    let hi: Vec<f64> = bkg.iter().map(|b| b * 1.06).collect();
    let lo: Vec<f64> = bkg.iter().map(|b| b * 0.95).collect();
    let st: Vec<f64> = bkg.iter().map(|b| b * 0.04).collect();
    let obs: Vec<f64> = bkg.iter().zip(&flat).map(|(b, f)| (b + f).round().max(0.0)).collect();
    let mut modifiers = vec![
        Json::obj(vec![
            ("name", Json::str("ns")),
            ("type", Json::str("normsys")),
            (
                "data",
                Json::obj(vec![("hi", Json::num(1.08)), ("lo", Json::num(0.93))]),
            ),
        ]),
        Json::obj(vec![
            ("name", Json::str("hs")),
            ("type", Json::str("histosys")),
            (
                "data",
                Json::obj(vec![
                    ("hi_data", Json::arr_f64(&hi)),
                    ("lo_data", Json::arr_f64(&lo)),
                ]),
            ),
        ]),
    ];
    if with_gamma {
        modifiers.push(Json::obj(vec![
            ("name", Json::str("st")),
            ("type", Json::str("staterror")),
            ("data", Json::arr_f64(&st)),
        ]));
    }
    let doc = Json::obj(vec![
        (
            "channels",
            Json::Arr(vec![Json::obj(vec![
                ("name", Json::str("SR")),
                (
                    "samples",
                    Json::Arr(vec![
                        Json::obj(vec![
                            ("name", Json::str("signal")),
                            ("data", Json::arr_f64(&sig)),
                            (
                                "modifiers",
                                Json::Arr(vec![Json::obj(vec![
                                    ("name", Json::str("mu")),
                                    ("type", Json::str("normfactor")),
                                    ("data", Json::Null),
                                ])]),
                            ),
                        ]),
                        Json::obj(vec![
                            ("name", Json::str("bkg")),
                            ("data", Json::arr_f64(&bkg)),
                            ("modifiers", Json::Arr(modifiers)),
                        ]),
                        Json::obj(vec![
                            ("name", Json::str("flat")),
                            ("data", Json::arr_f64(&flat)),
                            ("modifiers", Json::Arr(vec![])),
                        ]),
                    ]),
                ),
            ])]),
        ),
        (
            "observations",
            Json::Arr(vec![Json::obj(vec![
                ("name", Json::str("SR")),
                ("data", Json::arr_f64(&obs)),
            ])]),
        ),
        (
            "measurements",
            Json::Arr(vec![Json::obj(vec![
                ("name", Json::str("m")),
                (
                    "config",
                    Json::obj(vec![("poi", Json::str("mu")), ("parameters", Json::Arr(vec![]))]),
                ),
            ])]),
        ),
        ("version", Json::str("1.0.0")),
    ]);
    Workspace::from_json(&doc).unwrap()
}

fn exact_class(n_bins: usize, n_samples: usize, n_alpha: usize, n_free: usize) -> ShapeClass {
    ShapeClass {
        name: "edge".into(),
        n_bins,
        n_samples,
        n_alpha,
        n_free,
        bin_block: 8,
        mu_max: 10.0,
        max_newton: 48,
        cg_iters: 24,
    }
}

// ---------------------------------------------------------------------------
// batched vs sequential
// ---------------------------------------------------------------------------

#[test]
fn batched_nll_is_bitwise_equal_to_sequential_on_every_tier() {
    let _g = tier_lock();
    let initial = simd::active();
    let mut r = Rng::new(99);
    for t in simd::supported_tiers() {
        simd::force(t).unwrap();
        for i in 0..40 {
            let (ws, class) = gen_shape(&mut r);
            let m = compile(&ws, &class).unwrap_or_else(|e| panic!("batch shape {i}: {e}"));
            let fitter = NativeFitter::new(&m);
            let centers = Centers::nominal(&m);
            let k = 2 + r.below(5);
            let thetas: Vec<Vec<f64>> = (0..k).map(|_| rand_theta(&mut r, &m, &fitter)).collect();
            // per-patch data differ on the active bins (patched signals)
            let mut data2 = m.data.clone();
            for d in data2.iter_mut().take(m.n_active_bins) {
                *d = (*d + 1.0).round();
            }
            let models: Vec<&DenseModel> = vec![&m; k];
            let theta_refs: Vec<&[f64]> = thetas.iter().map(|v| v.as_slice()).collect();
            let datas: Vec<&[f64]> = (0..k)
                .map(|p| if p % 2 == 0 { &m.data[..] } else { &data2[..] })
                .collect();
            let center_refs: Vec<&Centers> = vec![&centers; k];

            let mut bws = NllBatch::for_class(&m.class, k);
            let mut out_b = vec![0.0; k];
            nll_batch(&models, &theta_refs, &datas, &center_refs, &mut bws, &mut out_b);

            let mut s = FitScratch::default();
            let mut out_s = vec![0.0; k];
            batch::nll_sequential(&models, &theta_refs, &datas, &center_refs, &mut s, &mut out_s);

            for p in 0..k {
                assert_eq!(
                    out_b[p].to_bits(),
                    out_s[p].to_bits(),
                    "batch shape {i} tier {} patch {p}: batched {} != sequential {}",
                    t.name(),
                    out_b[p],
                    out_s[p]
                );
            }
            // a too-small reused workspace regrows and still matches
            let mut small = NllBatch::for_class(&m.class, 1);
            let mut out_r = vec![0.0; k];
            nll_batch(&models, &theta_refs, &datas, &center_refs, &mut small, &mut out_r);
            for p in 0..k {
                assert_eq!(out_r[p].to_bits(), out_s[p].to_bits());
            }
        }
    }
    simd::force(initial).unwrap();
}

/// The forced-tier env override is honored end to end: whatever tier CI
/// pinned via `PYHF_FAAS_KERNEL_TIER` must actually be the active tier at
/// first use (force() calls in other tests run after this binary's first
/// dispatch only if this test runs first — hence the lock, and the check
/// tolerates an already-forced state by only asserting supportedness).
#[test]
fn active_tier_is_always_supported() {
    let _g = tier_lock();
    assert!(simd::supported(simd::active()));
}
