//! Property-based tests over the substrates, using the in-house
//! `util::proptest` harness (no proptest crate offline): randomized JSON
//! round-trips, patch inverses, dense-model invariants, scheduler laws and
//! asymptotic-formula laws.

use pyhf_faas::fitter::native::{asymptotic_cls, NativeFitter};
use pyhf_faas::histfactory::dense::{compile, ShapeClass};
use pyhf_faas::histfactory::spec::Workspace;
use pyhf_faas::sim::cluster::{simulate, CostModel, Topology};
use pyhf_faas::util::json::{self, Json};
use pyhf_faas::util::proptest::{forall, Gen};

// ---------------------------------------------------------------------------
// JSON round trips
// ---------------------------------------------------------------------------

fn random_json(g: &mut Gen, depth: usize) -> Json {
    let choice = g.usize_in(0, if depth == 0 { 3 } else { 5 });
    match choice {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num((g.f64_in(-1e6, 1e6) * 8.0).round() / 8.0),
        3 => {
            let len = g.usize_in(0, 8);
            Json::Str(
                (0..len)
                    .map(|_| {
                        let c = g.usize_in(0, 4);
                        match c {
                            0 => '"',
                            1 => '\\',
                            2 => 'é',
                            _ => (b'a' + g.usize_in(0, 25) as u8) as char,
                        }
                    })
                    .collect(),
            )
        }
        4 => {
            let len = g.usize_in(0, 4);
            Json::Arr((0..len).map(|_| random_json(g, depth - 1)).collect())
        }
        _ => {
            let len = g.usize_in(0, 4);
            Json::Obj(
                (0..len)
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_json_roundtrip_compact_and_pretty() {
    forall(11, 300, |g| random_json(g, 3), |doc| {
        let compact = json::parse(&json::to_string(doc)).unwrap();
        let pretty = json::parse(&json::to_string_pretty(doc)).unwrap();
        compact == *doc && pretty == *doc
    });
}

#[test]
fn prop_patch_add_then_remove_is_identity() {
    forall(13, 200, |g| (random_json(g, 2), g.usize_in(0, 6)), |(value, slot)| {
        let mut doc = json::parse(r#"{"channels": [1, 2, 3], "version": "1.0.0"}"#).unwrap();
        let original = doc.clone();
        let idx = (*slot).min(3);
        let add = Json::Arr(vec![Json::obj(vec![
            ("op", Json::str("add")),
            ("path", Json::str(format!("/channels/{idx}"))),
            ("value", value.clone()),
        ])]);
        let remove = Json::Arr(vec![Json::obj(vec![
            ("op", Json::str("remove")),
            ("path", Json::str(format!("/channels/{idx}"))),
        ])]);
        json::apply_patch(&mut doc, &add).unwrap();
        json::apply_patch(&mut doc, &remove).unwrap();
        doc == original
    });
}

// ---------------------------------------------------------------------------
// dense model invariants
// ---------------------------------------------------------------------------

fn tiny_class() -> ShapeClass {
    ShapeClass {
        name: "quickstart".into(),
        n_bins: 16,
        n_samples: 6,
        n_alpha: 6,
        n_free: 2,
        bin_block: 16,
        mu_max: 10.0,
        max_newton: 48,
        cg_iters: 24,
    }
}

fn two_channel_ws(s1: f64, s2: f64, b1: f64, b2: f64, o1: f64, o2: f64) -> Workspace {
    let doc = format!(
        r#"{{
        "channels": [
            {{"name": "A", "samples": [
                {{"name": "signal", "data": [{s1}],
                 "modifiers": [{{"name": "mu", "type": "normfactor", "data": null}}]}},
                {{"name": "bkg", "data": [{b1}], "modifiers": []}}
            ]}},
            {{"name": "B", "samples": [
                {{"name": "signal", "data": [{s2}],
                 "modifiers": [{{"name": "mu", "type": "normfactor", "data": null}}]}},
                {{"name": "bkg", "data": [{b2}], "modifiers": []}}
            ]}}
        ],
        "observations": [
            {{"name": "A", "data": [{o1}]}},
            {{"name": "B", "data": [{o2}]}}
        ],
        "measurements": [{{"name": "m", "config": {{"poi": "mu", "parameters": []}}}}],
        "version": "1.0.0"
    }}"#
    );
    Workspace::from_str(&doc).unwrap()
}

#[test]
fn prop_expected_rates_linear_in_mu() {
    forall(17, 60, |g| {
        (
            g.f64_in(0.5, 8.0),  // signal 1
            g.f64_in(0.5, 8.0),  // signal 2
            g.f64_in(20.0, 90.0), // bkg 1
            g.f64_in(20.0, 90.0), // bkg 2
            g.f64_in(0.2, 6.0),  // mu
        )
    }, |&(s1, s2, b1, b2, mu)| {
        let ws = two_channel_ws(s1, s2, b1, b2, b1, b2);
        let m = compile(&ws, &tiny_class()).unwrap();
        let fitter = NativeFitter::new(&m);
        let mut th = fitter.init_theta(mu);
        let (nu_mu, _) = fitter.expected_jac(&th);
        th[0] = 0.0f64.max(1e-10);
        let (nu_0, _) = fitter.expected_jac(&th);
        // nu(mu) = bkg + mu * sig in every active bin
        let ok1 = (nu_mu[0] - (b1 + mu * s1)).abs() < 1e-9 * (1.0 + b1);
        let ok2 = (nu_mu[1] - (b2 + mu * s2)).abs() < 1e-9 * (1.0 + b2);
        let ok3 = (nu_0[0] - b1).abs() < 1e-6;
        ok1 && ok2 && ok3
    });
}

#[test]
fn prop_channel_order_does_not_change_nll_at_init() {
    forall(19, 60, |g| {
        (
            g.f64_in(0.5, 8.0),
            g.f64_in(0.5, 8.0),
            g.f64_in(20.0, 90.0),
            g.f64_in(20.0, 90.0),
        )
    }, |&(s1, s2, b1, b2)| {
        let class = tiny_class();
        let wa = two_channel_ws(s1, s2, b1, b2, b1 + 1.0, b2 - 1.0);
        // swapped channel order (and matching observations)
        let wb = two_channel_ws(s2, s1, b2, b1, b2 - 1.0, b1 + 1.0);
        let ma = compile(&wa, &class).unwrap();
        let mb = compile(&wb, &class).unwrap();
        let fa = NativeFitter::new(&ma);
        let fb = NativeFitter::new(&mb);
        let ca = pyhf_faas::fitter::Centers::nominal(&ma);
        let cb = pyhf_faas::fitter::Centers::nominal(&mb);
        let na = fa.nll(&fa.init_theta(1.0), &ma.data, &ca);
        let nb = fb.nll(&fb.init_theta(1.0), &mb.data, &cb);
        (na - nb).abs() < 1e-9 * (1.0 + na.abs())
    });
}

// ---------------------------------------------------------------------------
// scheduler laws
// ---------------------------------------------------------------------------

#[test]
fn prop_makespan_bounds() {
    forall(23, 100, |g| {
        let n = g.usize_in(1, 40);
        let svc = g.vec_f64(n, 0.1, 5.0);
        let workers = g.usize_in(1, 8);
        (svc, workers)
    }, |(svc, workers)| {
        let topo = Topology { max_blocks: 1, nodes_per_block: 1, workers_per_node: *workers };
        let out = simulate(svc, topo, CostModel::ideal(), 5);
        let total: f64 = svc.iter().sum();
        let longest = svc.iter().cloned().fold(0.0, f64::max);
        // classic list-scheduling bounds: max(longest, total/m) <= makespan <= total
        out.makespan_s >= longest - 1e-9
            && out.makespan_s >= total / *workers as f64 - 1e-9
            && out.makespan_s <= total + 1e-9
            && out.completions_s.len() == svc.len()
    });
}

// ---------------------------------------------------------------------------
// asymptotic formula laws
// ---------------------------------------------------------------------------

#[test]
fn prop_asymptotic_cls_laws() {
    forall(29, 300, |g| (g.f64_in(0.0, 30.0), g.f64_in(0.01, 30.0)), |&(qmu, qmu_a)| {
        let (cls, exp) = asymptotic_cls(qmu, qmu_a);
        let in_range = (0.0..=1.0 + 1e-9).contains(&cls)
            && exp.iter().all(|e| (0.0..=1.0 + 1e-9).contains(e));
        let band_monotone = exp.windows(2).all(|w| w[0] <= w[1] + 1e-12);
        // CLs decreases as the observed qmu grows (for fixed qmu_A)
        let (cls_hi, _) = asymptotic_cls(qmu + 1.0, qmu_a);
        in_range && band_monotone && cls_hi <= cls + 1e-9
    });
}
