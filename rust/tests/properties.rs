//! Property-based tests over the substrates, using the in-house
//! `util::proptest` harness (no proptest crate offline): randomized JSON
//! round-trips, patch inverses, dense-model invariants, scheduler laws and
//! asymptotic-formula laws.

use pyhf_faas::fitter::native::{asymptotic_cls, NativeFitter};
use pyhf_faas::fitter::{BaselineFitter, Centers};
use pyhf_faas::histfactory::dense::{compile, ShapeClass};
use pyhf_faas::histfactory::spec::Workspace;
use pyhf_faas::sim::cluster::{simulate, CostModel, Topology};
use pyhf_faas::util::json::{self, Json};
use pyhf_faas::util::proptest::{forall, Gen};

// ---------------------------------------------------------------------------
// JSON round trips
// ---------------------------------------------------------------------------

fn random_json(g: &mut Gen, depth: usize) -> Json {
    let choice = g.usize_in(0, if depth == 0 { 3 } else { 5 });
    match choice {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num((g.f64_in(-1e6, 1e6) * 8.0).round() / 8.0),
        3 => {
            let len = g.usize_in(0, 8);
            Json::Str(
                (0..len)
                    .map(|_| {
                        let c = g.usize_in(0, 4);
                        match c {
                            0 => '"',
                            1 => '\\',
                            2 => 'é',
                            _ => (b'a' + g.usize_in(0, 25) as u8) as char,
                        }
                    })
                    .collect(),
            )
        }
        4 => {
            let len = g.usize_in(0, 4);
            Json::Arr((0..len).map(|_| random_json(g, depth - 1)).collect())
        }
        _ => {
            let len = g.usize_in(0, 4);
            Json::Obj(
                (0..len)
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_json_roundtrip_compact_and_pretty() {
    forall(11, 300, |g| random_json(g, 3), |doc| {
        let compact = json::parse(&json::to_string(doc)).unwrap();
        let pretty = json::parse(&json::to_string_pretty(doc)).unwrap();
        compact == *doc && pretty == *doc
    });
}

#[test]
fn prop_patch_add_then_remove_is_identity() {
    forall(13, 200, |g| (random_json(g, 2), g.usize_in(0, 6)), |(value, slot)| {
        let mut doc = json::parse(r#"{"channels": [1, 2, 3], "version": "1.0.0"}"#).unwrap();
        let original = doc.clone();
        let idx = (*slot).min(3);
        let add = Json::Arr(vec![Json::obj(vec![
            ("op", Json::str("add")),
            ("path", Json::str(format!("/channels/{idx}"))),
            ("value", value.clone()),
        ])]);
        let remove = Json::Arr(vec![Json::obj(vec![
            ("op", Json::str("remove")),
            ("path", Json::str(format!("/channels/{idx}"))),
        ])]);
        json::apply_patch(&mut doc, &add).unwrap();
        json::apply_patch(&mut doc, &remove).unwrap();
        doc == original
    });
}

// ---------------------------------------------------------------------------
// dense model invariants
// ---------------------------------------------------------------------------

fn tiny_class() -> ShapeClass {
    ShapeClass {
        name: "quickstart".into(),
        n_bins: 16,
        n_samples: 6,
        n_alpha: 6,
        n_free: 2,
        bin_block: 16,
        mu_max: 10.0,
        max_newton: 48,
        cg_iters: 24,
    }
}

fn two_channel_ws(s1: f64, s2: f64, b1: f64, b2: f64, o1: f64, o2: f64) -> Workspace {
    let doc = format!(
        r#"{{
        "channels": [
            {{"name": "A", "samples": [
                {{"name": "signal", "data": [{s1}],
                 "modifiers": [{{"name": "mu", "type": "normfactor", "data": null}}]}},
                {{"name": "bkg", "data": [{b1}], "modifiers": []}}
            ]}},
            {{"name": "B", "samples": [
                {{"name": "signal", "data": [{s2}],
                 "modifiers": [{{"name": "mu", "type": "normfactor", "data": null}}]}},
                {{"name": "bkg", "data": [{b2}], "modifiers": []}}
            ]}}
        ],
        "observations": [
            {{"name": "A", "data": [{o1}]}},
            {{"name": "B", "data": [{o2}]}}
        ],
        "measurements": [{{"name": "m", "config": {{"poi": "mu", "parameters": []}}}}],
        "version": "1.0.0"
    }}"#
    );
    Workspace::from_str(&doc).unwrap()
}

#[test]
fn prop_expected_rates_linear_in_mu() {
    forall(17, 60, |g| {
        (
            g.f64_in(0.5, 8.0),  // signal 1
            g.f64_in(0.5, 8.0),  // signal 2
            g.f64_in(20.0, 90.0), // bkg 1
            g.f64_in(20.0, 90.0), // bkg 2
            g.f64_in(0.2, 6.0),  // mu
        )
    }, |&(s1, s2, b1, b2, mu)| {
        let ws = two_channel_ws(s1, s2, b1, b2, b1, b2);
        let m = compile(&ws, &tiny_class()).unwrap();
        let fitter = NativeFitter::new(&m);
        let mut th = fitter.init_theta(mu);
        let (nu_mu, _) = fitter.expected_jac(&th);
        th[0] = 0.0f64.max(1e-10);
        let (nu_0, _) = fitter.expected_jac(&th);
        // nu(mu) = bkg + mu * sig in every active bin
        let ok1 = (nu_mu[0] - (b1 + mu * s1)).abs() < 1e-9 * (1.0 + b1);
        let ok2 = (nu_mu[1] - (b2 + mu * s2)).abs() < 1e-9 * (1.0 + b2);
        let ok3 = (nu_0[0] - b1).abs() < 1e-6;
        ok1 && ok2 && ok3
    });
}

#[test]
fn prop_channel_order_does_not_change_nll_at_init() {
    forall(19, 60, |g| {
        (
            g.f64_in(0.5, 8.0),
            g.f64_in(0.5, 8.0),
            g.f64_in(20.0, 90.0),
            g.f64_in(20.0, 90.0),
        )
    }, |&(s1, s2, b1, b2)| {
        let class = tiny_class();
        let wa = two_channel_ws(s1, s2, b1, b2, b1 + 1.0, b2 - 1.0);
        // swapped channel order (and matching observations)
        let wb = two_channel_ws(s2, s1, b2, b1, b2 - 1.0, b1 + 1.0);
        let ma = compile(&wa, &class).unwrap();
        let mb = compile(&wb, &class).unwrap();
        let fa = NativeFitter::new(&ma);
        let fb = NativeFitter::new(&mb);
        let ca = pyhf_faas::fitter::Centers::nominal(&ma);
        let cb = pyhf_faas::fitter::Centers::nominal(&mb);
        let na = fa.nll(&fa.init_theta(1.0), &ma.data, &ca);
        let nb = fb.nll(&fb.init_theta(1.0), &mb.data, &cb);
        (na - nb).abs() < 1e-9 * (1.0 + na.abs())
    });
}

// ---------------------------------------------------------------------------
// fused kernel laws (ISSUE 2)
// ---------------------------------------------------------------------------

/// Random one-channel workspace exercising every modifier family the dense
/// kernel handles: normfactor, normsys, histosys, staterror.
fn rand_ws(g: &mut Gen) -> Workspace {
    let nb = 2 + g.usize_in(0, 2); // 2..=4 bins
    let sig: Vec<f64> = g.vec_f64(nb, 0.5, 8.0);
    let bkg: Vec<f64> = g.vec_f64(nb, 25.0, 95.0);
    let obs: Vec<f64> = bkg.iter().map(|b| (b + g.f64_in(-4.0, 8.0)).max(1.0).round()).collect();
    let hi: Vec<f64> = bkg.iter().map(|b| b * (1.0 + g.f64_in(0.01, 0.12))).collect();
    let lo: Vec<f64> = bkg.iter().map(|b| b * (1.0 - g.f64_in(0.01, 0.12))).collect();
    let st: Vec<f64> = bkg.iter().map(|b| (b * g.f64_in(0.02, 0.08)).max(0.3)).collect();
    let fmt = |v: &[f64]| {
        v.iter().map(|x| format!("{x:.4}")).collect::<Vec<_>>().join(", ")
    };
    let kappa_hi = 1.0 + g.f64_in(0.02, 0.2);
    let kappa_lo = 1.0 - g.f64_in(0.02, 0.2);
    let doc = format!(
        r#"{{
        "channels": [{{"name": "SR", "samples": [
            {{"name": "signal", "data": [{sig}],
             "modifiers": [{{"name": "mu", "type": "normfactor", "data": null}}]}},
            {{"name": "bkg", "data": [{bkg}],
             "modifiers": [
                {{"name": "bn", "type": "normsys",
                 "data": {{"hi": {kappa_hi:.4}, "lo": {kappa_lo:.4}}}}},
                {{"name": "tilt", "type": "histosys",
                 "data": {{"hi_data": [{hi}], "lo_data": [{lo}]}}}},
                {{"name": "st", "type": "staterror", "data": [{st}]}}
             ]}}
        ]}}],
        "observations": [{{"name": "SR", "data": [{obs}]}}],
        "measurements": [{{"name": "m", "config": {{"poi": "mu", "parameters": []}}}}],
        "version": "1.0.0"
    }}"#,
        sig = fmt(&sig),
        bkg = fmt(&bkg),
        hi = fmt(&hi),
        lo = fmt(&lo),
        st = fmt(&st),
        obs = fmt(&obs),
    );
    Workspace::from_str(&doc).unwrap()
}

#[test]
fn prop_fused_nll_grad_fisher_matches_unfused_and_finite_differences() {
    forall(37, 30, |g| {
        (rand_ws(g), g.f64_in(0.3, 3.0), g.f64_in(-1.5, 1.5), g.f64_in(0.9, 1.1))
    }, |(ws, mu, al, gam)| {
        let m = compile(ws, &tiny_class()).unwrap();
        let fused = NativeFitter::new(&m);
        let seed = BaselineFitter::new(&m);
        let centers = Centers::nominal(&m);
        let p_ = m.class.n_params();
        let f_ = m.class.n_free;
        let a_ = m.class.n_alpha;

        let mut theta = fused.init_theta(*mu);
        theta[f_] = *al; // normsys alpha
        theta[f_ + 1] = -*al; // histosys alpha, opposite side
        for b in 0..m.n_active_bins {
            if m.ctype[b] > 0.0 {
                theta[f_ + a_ + b] = *gam;
            }
        }

        // 1. fused NLL equals the unfused seed NLL (the seed additionally
        // counts a clipped EPS_RATE per padded sample row: ~1e-9 absolute)
        let n_fused = fused.nll(&theta, &m.data, &centers);
        let n_seed = seed.nll(&theta, &m.data, &centers);
        if (n_fused - n_seed).abs() > 1e-6 * (1.0 + n_seed.abs()) {
            return false;
        }

        // 2. fused analytic gradient equals central finite differences of
        // the fused NLL on every non-fixed parameter
        let fixed = fused.fixed_mask(false);
        let (grad, _) = fused.grad_fisher(&theta, &m.data, &centers, &fixed);
        let eps = 1e-6;
        for p in 0..p_ {
            if fixed[p] {
                if grad[p] != 0.0 {
                    return false;
                }
                continue;
            }
            let mut tp = theta.clone();
            tp[p] += eps;
            let up = fused.nll(&tp, &m.data, &centers);
            tp[p] -= 2.0 * eps;
            let dn = fused.nll(&tp, &m.data, &centers);
            let fd = (up - dn) / (2.0 * eps);
            if (fd - grad[p]).abs() > 2e-3 * (1.0 + grad[p].abs()) {
                return false;
            }
        }
        true
    });
}

#[test]
fn padded_and_compact_evaluations_are_bit_identical() {
    // the same workspace compiled into an exactly-fitting class and into a
    // much larger padded class (with a different bin_block tile) must
    // produce bit-identical NLLs and fits: the fused kernel sweeps only
    // the active region, so padding cannot perturb the arithmetic
    let ws = Workspace::from_str(
        r#"{
        "channels": [
            {"name": "SR", "samples": [
                {"name": "signal", "data": [3.0, 5.0, 2.0],
                 "modifiers": [{"name": "mu", "type": "normfactor", "data": null}]},
                {"name": "bkg", "data": [60.0, 50.0, 40.0],
                 "modifiers": [
                    {"name": "bn", "type": "normsys", "data": {"hi": 1.08, "lo": 0.93}},
                    {"name": "tilt", "type": "histosys",
                     "data": {"hi_data": [62.0, 49.0, 41.0], "lo_data": [58.0, 51.0, 39.0]}},
                    {"name": "st", "type": "staterror", "data": [2.0, 1.8, 1.5]}
                 ]}
            ]},
            {"name": "CR", "samples": [
                {"name": "bkg", "data": [100.0, 90.0],
                 "modifiers": [
                    {"name": "bn", "type": "normsys", "data": {"hi": 1.1, "lo": 0.9}},
                    {"name": "dd", "type": "shapesys", "data": [10.0, 9.0]}
                 ]}
            ]}
        ],
        "observations": [
            {"name": "SR", "data": [64.0, 54.0, 42.0]},
            {"name": "CR", "data": [101.0, 88.0]}
        ],
        "measurements": [{"name": "m", "config": {"poi": "mu", "parameters": []}}],
        "version": "1.0.0"
    }"#,
    )
    .unwrap();

    let exact = ShapeClass {
        name: "exact".into(),
        n_bins: 5,
        n_samples: 3,
        n_alpha: 3,
        n_free: 1,
        bin_block: 16,
        mu_max: 10.0,
        max_newton: 48,
        cg_iters: 24,
    };
    let padded = ShapeClass {
        name: "padded".into(),
        n_bins: 64,
        n_samples: 24,
        n_alpha: 24,
        n_free: 4,
        bin_block: 8, // different tile: tiling must not change the sums
        mu_max: 10.0,
        max_newton: 48,
        cg_iters: 24,
    };
    let me = compile(&ws, &exact).unwrap();
    let mp = compile(&ws, &padded).unwrap();
    assert_eq!(me.n_active_bins, mp.n_active_bins);
    assert_eq!(me.n_active_rows, mp.n_active_rows);
    assert_eq!(me.n_active_alpha, mp.n_active_alpha);

    let fe = NativeFitter::new(&me);
    let fp = NativeFitter::new(&mp);
    let ce = Centers::nominal(&me);
    let cp = Centers::nominal(&mp);

    // same point, expressed in each class's parameter layout
    let build_theta = |m: &pyhf_faas::histfactory::dense::DenseModel,
                       f: &NativeFitter| -> Vec<f64> {
        let mut th = f.init_theta(1.3);
        let (f_, a_) = (m.class.n_free, m.class.n_alpha);
        th[f_] = 0.37;
        th[f_ + 1] = -0.52;
        th[f_ + 2] = 0.11;
        for b in 0..m.n_active_bins {
            if m.ctype[b] > 0.0 {
                th[f_ + a_ + b] = 1.07;
            }
        }
        th
    };
    let te = build_theta(&me, &fe);
    let tp = build_theta(&mp, &fp);

    // the property must hold on every SIMD tier the CPU can run: the
    // kernels sweep (and reduce over) only the active region, and the
    // reduction order within a tier depends only on the active counts and
    // the lane width — never on the padding
    let initial = pyhf_faas::fitter::simd::active();
    for tier in pyhf_faas::fitter::simd::supported_tiers() {
        pyhf_faas::fitter::simd::force(tier).unwrap();
        let tn = tier.name();

        let ne = fe.nll(&te, &me.data, &ce);
        let np = fp.nll(&tp, &mp.data, &cp);
        assert_eq!(ne.to_bits(), np.to_bits(), "tier {tn}: padded NLL {np} != compact NLL {ne}");

        // full fits walk the identical Newton trajectory bit for bit
        let re = fe.fit_free(&me.data, &ce);
        let rp = fp.fit_free(&mp.data, &cp);
        assert_eq!(re.nll.to_bits(), rp.nll.to_bits(), "tier {tn}: fit NLLs diverge");
        assert_eq!(re.theta[0].to_bits(), rp.theta[0].to_bits(), "tier {tn}: fit POIs diverge");
        assert_eq!(re.accepted_steps, rp.accepted_steps, "tier {tn}: fit trajectories diverge");
    }
    pyhf_faas::fitter::simd::force(initial).unwrap();
}

// ---------------------------------------------------------------------------
// scheduler laws
// ---------------------------------------------------------------------------

#[test]
fn prop_makespan_bounds() {
    forall(23, 100, |g| {
        let n = g.usize_in(1, 40);
        let svc = g.vec_f64(n, 0.1, 5.0);
        let workers = g.usize_in(1, 8);
        (svc, workers)
    }, |(svc, workers)| {
        let topo = Topology { max_blocks: 1, nodes_per_block: 1, workers_per_node: *workers };
        let out = simulate(svc, topo, CostModel::ideal(), 5);
        let total: f64 = svc.iter().sum();
        let longest = svc.iter().cloned().fold(0.0, f64::max);
        // classic list-scheduling bounds: max(longest, total/m) <= makespan <= total
        out.makespan_s >= longest - 1e-9
            && out.makespan_s >= total / *workers as f64 - 1e-9
            && out.makespan_s <= total + 1e-9
            && out.completions_s.len() == svc.len()
    });
}

// ---------------------------------------------------------------------------
// asymptotic formula laws
// ---------------------------------------------------------------------------

#[test]
fn prop_asymptotic_cls_laws() {
    forall(29, 300, |g| (g.f64_in(0.0, 30.0), g.f64_in(0.01, 30.0)), |&(qmu, qmu_a)| {
        let (cls, exp) = asymptotic_cls(qmu, qmu_a);
        let in_range = (0.0..=1.0 + 1e-9).contains(&cls)
            && exp.iter().all(|e| (0.0..=1.0 + 1e-9).contains(e));
        let band_monotone = exp.windows(2).all(|w| w[0] <= w[1] + 1e-12);
        // CLs decreases as the observed qmu grows (for fixed qmu_A)
        let (cls_hi, _) = asymptotic_cls(qmu + 1.0, qmu_a);
        in_range && band_monotone && cls_hi <= cls + 1e-9
    });
}
