//! End-to-end task-reliability properties against the live stack:
//! bounded retry with a budget, worker- and client-side deadline
//! enforcement (typed outcome), hedged execution rescuing a lost result,
//! task migration off a quarantined endpoint, and probe-gated
//! readmission. The chaos harness is process-global, so the tests that
//! install a plan serialize on one lock.

use std::collections::HashSet;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use pyhf_faas::coordinator::chaos;
use pyhf_faas::coordinator::reliability::is_deadline_exceeded;
use pyhf_faas::coordinator::{
    ChaosFault, ChaosPlan, ChaosRule, Endpoint, EndpointConfig, ExecutorConfig, FaasClient,
    HedgePolicy, ReliabilityPolicy, RetryPolicy, Service, ServiceHandle, TaskState,
};
use pyhf_faas::scheduler::{HealthConfig, PolicyKind, RouteStrategyKind, Router};
use pyhf_faas::util::json::Json;

fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn quick_endpoint(svc: &ServiceHandle, name: &str, workers: usize) -> Endpoint {
    Endpoint::start(
        svc.clone(),
        EndpointConfig::new(name)
            .with_executor(ExecutorConfig {
                max_blocks: 1,
                nodes_per_block: 1,
                workers_per_node: workers,
                parallelism: 1.0,
                poll: Duration::from_millis(1),
            })
            .with_policy(PolicyKind::Affinity),
    )
}

fn patch(i: usize) -> Json {
    Json::obj(vec![("patch", Json::str(format!("p{i}"))), ("class", Json::str("A"))])
}

fn wait_running(svc: &ServiceHandle, id: pyhf_faas::coordinator::TaskId) {
    let t0 = Instant::now();
    while svc.task_state(id) != Some(TaskState::Running) {
        assert!(t0.elapsed() < Duration::from_secs(5), "task {id} never started running");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn retry_recovers_transient_failures() {
    let svc = Service::new();
    let ep = quick_endpoint(&svc, "rel-retry", 2);
    let client = FaasClient::new(svc.clone()).with_reliability(
        ReliabilityPolicy::new().with_retry(RetryPolicy {
            max_attempts: 3,
            backoff_base: Duration::from_millis(5),
            ..Default::default()
        }),
    );
    // every payload fails its first execution and succeeds afterwards
    let seen: Arc<Mutex<HashSet<String>>> = Arc::new(Mutex::new(HashSet::new()));
    let f = client.register_function("flaky", {
        let seen = seen.clone();
        Arc::new(move |p: &Json, _: &mut _| {
            let key = p.get("patch").and_then(|v| v.as_str()).unwrap_or("?").to_string();
            if seen.lock().unwrap().insert(key) {
                Err("transient synthetic failure".to_string())
            } else {
                Ok(p.clone())
            }
        })
    });

    let n = 6usize;
    let tasks: Vec<_> = (0..n).map(|i| client.run(patch(i), ep.id, f).unwrap()).collect();
    let results = client
        .gather(&tasks, Duration::from_secs(20), Duration::from_millis(1), None, |_, _| {})
        .expect("gather");
    ep.shutdown();

    assert!(results.iter().all(|r| r.is_ok()), "retries must mask the transient failures");
    let m = svc.metrics.snapshot();
    assert_eq!(m.retries, n as u64, "each logical task retries exactly once");
    assert_eq!(m.completed, n as u64);
    assert_eq!(m.failed, n as u64, "the failed first attempts stay ledger-counted");
    // every physical submission (first attempts + retries) is terminal
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
    assert_eq!(m.submitted, 2 * n as u64);
}

#[test]
fn retry_budget_exhausts_to_fail_fast() {
    let svc = Service::new();
    let ep = quick_endpoint(&svc, "rel-budget", 2);
    let client = FaasClient::new(svc.clone()).with_reliability(
        ReliabilityPolicy::new().with_retry(RetryPolicy {
            max_attempts: 5,
            backoff_base: Duration::from_millis(2),
            budget_ratio: 0.0,
            budget_min: 2,
            ..Default::default()
        }),
    );
    let f = client.register_function(
        "doomed",
        Arc::new(|_: &Json, _: &mut _| Err("synthetic hard failure".to_string())),
    );

    let tasks: Vec<_> = (0..4).map(|i| client.run(patch(i), ep.id, f).unwrap()).collect();
    let results = client
        .gather(&tasks, Duration::from_secs(20), Duration::from_millis(1), None, |_, _| {})
        .expect("gather");
    ep.shutdown();

    for r in &results {
        let err = r.as_ref().expect_err("a permanently failing task must fail");
        assert!(err.contains("synthetic"), "{err}");
    }
    let m = svc.metrics.snapshot();
    // budget_min=2 with ratio 0: exactly two retries total across the
    // wave, then the remaining failures degrade to fail-fast
    assert_eq!(m.retries, 2, "budget must bound resubmissions");
    assert_eq!(m.completed, 0);
    assert_eq!(m.failed, 4 + 2);
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
}

#[test]
fn workers_drop_expired_tasks_at_pop() {
    let svc = Service::new();
    let ep = quick_endpoint(&svc, "rel-expire", 1);
    let echo = svc.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));
    let slow = svc.register_function(
        "blocker",
        Arc::new(|p: &Json, _: &mut _| {
            std::thread::sleep(Duration::from_millis(400));
            Ok(p.clone())
        }),
    );

    // occupy the only worker, then queue tasks whose deadline passes
    // while they wait: the pop boundary must drop them unexecuted
    let blocker = svc.submit(ep.id, slow, Json::num(0.0)).unwrap();
    wait_running(&svc, blocker);
    let deadline = Some(Instant::now() + Duration::from_millis(50));
    let doomed: Vec<_> = (0..4)
        .map(|i| svc.submit_with_deadline(ep.id, echo, patch(i), deadline).unwrap())
        .collect();

    svc.wait_result(blocker, Duration::from_secs(10)).expect("blocker");
    for id in &doomed {
        let err = svc
            .wait_result(*id, Duration::from_secs(10))
            .expect_err("an expired task must fail, not run");
        assert!(is_deadline_exceeded(&err), "untyped deadline outcome: {err}");
    }
    ep.shutdown();

    let m = svc.metrics.snapshot();
    assert_eq!(m.deadline_exceeded, 4);
    assert_eq!(m.failed, 4, "worker-side expiry lands in the failed bucket");
    assert_eq!(m.completed, 1);
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
}

#[test]
fn client_deadline_bounds_lost_results() {
    let _g = chaos_lock();
    chaos::clear();

    let svc = Service::new();
    let ep = quick_endpoint(&svc, "rel-lost", 1);
    let client = FaasClient::new(svc.clone()).with_reliability(
        ReliabilityPolicy::new().with_task_deadline(Duration::from_millis(300)),
    );
    let f = client.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));

    // the task executes but its result never reaches the service: without
    // the deadline the client would poll forever
    chaos::install(ChaosPlan::new(0xdead).rule(ChaosRule::new(ChaosFault::DropResult, None, 0, 1)));
    let t = client.run(patch(0), ep.id, f).unwrap();
    let results = client
        .gather(&[t], Duration::from_secs(10), Duration::from_millis(2), None, |_, _| {})
        .expect("gather resolves every slot despite the lost result");
    let plan = chaos::clear().expect("plan still installed");
    ep.shutdown();

    assert_eq!(plan.total_hits(), 1, "the drop-result fault must have fired");
    let err = results[0].as_ref().expect_err("lost result must finalize as an error");
    assert!(is_deadline_exceeded(err), "untyped deadline outcome: {err}");
    let m = svc.metrics.snapshot();
    assert_eq!(m.deadline_exceeded, 1);
    assert_eq!(m.cancelled, 1, "the abandoned attempt lands in the cancelled bucket");
    assert_eq!(m.completed, 0);
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
}

#[test]
fn hedge_rescues_dropped_result() {
    let _g = chaos_lock();
    chaos::clear();

    let svc = Service::new();
    let ep0 = quick_endpoint(&svc, "rel-hedge0", 2);
    let ep1 = quick_endpoint(&svc, "rel-hedge1", 2);
    let mut router = Router::new(RouteStrategyKind::LeastLoaded);
    router.add_target(ep0.id, 0, ep0.probe());
    router.add_target(ep1.id, 1, ep1.probe());
    svc.install_router(router);

    let client = FaasClient::new(svc.clone()).with_reliability(
        ReliabilityPolicy::new().with_hedge(HedgePolicy {
            after_p99: 2.0,
            min_observations: 20,
            // well above the warm-up wave's worst-case latency, so only
            // the genuinely stuck task ever crosses the hedge threshold
            min_age: Duration::from_millis(250),
        }),
    );
    let f = client.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));

    // warm the p99 sketch past min_observations so the hedge threshold
    // is trusted
    let warmup: Vec<_> = (0..40).map(|i| client.run_routed(patch(i), f).unwrap()).collect();
    client
        .gather(&warmup, Duration::from_secs(20), Duration::from_millis(1), None, |_, _| {})
        .expect("warmup gather");

    // lose exactly the next delivered result: the straggling primary can
    // only be rescued by the speculative duplicate on the other endpoint
    chaos::install(ChaosPlan::new(0xbeef).rule(ChaosRule::new(ChaosFault::DropResult, None, 0, 1)));
    let t = client.run_routed(patch(99), f).unwrap();
    let results = client
        .gather(&[t], Duration::from_secs(20), Duration::from_millis(2), None, |_, _| {})
        .expect("gather");
    let plan = chaos::clear().expect("plan still installed");
    ep0.shutdown();
    ep1.shutdown();

    assert_eq!(plan.total_hits(), 1);
    assert!(results[0].is_ok(), "hedge must deliver the result: {:?}", results[0]);
    let m = svc.metrics.snapshot();
    assert!(m.hedges >= 1, "no speculative duplicate was launched");
    assert!(m.hedge_wins >= 1, "the duplicate's result must win");
    assert!(m.cancelled >= 1, "the stuck primary is cancelled, not leaked in flight");
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
}

#[test]
fn quarantine_migrates_queued_tasks() {
    let svc = Service::new();
    let ep0 = quick_endpoint(&svc, "rel-mig0", 1);
    let ep1 = quick_endpoint(&svc, "rel-mig1", 2);
    let mut router = Router::new(RouteStrategyKind::LeastLoaded).with_health_config(HealthConfig {
        stall_after: Duration::from_millis(100),
        backoff_base: Duration::from_secs(10),
        backoff_max: Duration::from_secs(10),
        ..Default::default()
    });
    router.add_target(ep0.id, 0, ep0.probe());
    router.add_target(ep1.id, 1, ep1.probe());
    svc.install_router(router);

    let client = FaasClient::new(svc.clone());
    let echo = svc.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));
    let slow = svc.register_function(
        "blocker",
        Arc::new(|p: &Json, _: &mut _| {
            std::thread::sleep(Duration::from_secs(2));
            Ok(p.clone())
        }),
    );

    // wedge ep0: its only worker runs the blocker while real work queues
    // behind it
    let blocker = svc.submit(ep0.id, slow, Json::num(0.0)).unwrap();
    wait_running(&svc, blocker);
    let queued: Vec<_> = (0..3).map(|i| svc.submit(ep0.id, echo, patch(i)).unwrap()).collect();

    // first routed decision anchors ep0's stall clock; the second, past
    // stall_after, quarantines it and recalls the queued tasks
    let t1 = client.run_routed(patch(10), echo).unwrap();
    client.wait(t1, Duration::from_secs(10)).expect("trigger 1");
    std::thread::sleep(Duration::from_millis(200));
    let t2 = client.run_routed(patch(11), echo).unwrap();
    client.wait(t2, Duration::from_secs(10)).expect("trigger 2");

    // the recalled tasks must complete on the healthy endpoint long
    // before ep0's blocker would have freed its worker
    for id in &queued {
        svc.wait_result(*id, Duration::from_secs(10)).expect("migrated task must complete");
    }
    svc.wait_result(blocker, Duration::from_secs(10)).expect("blocker");
    ep0.shutdown();
    ep1.shutdown();

    let m = svc.metrics.snapshot();
    assert!(m.endpoints_quarantined >= 1, "the wedged endpoint was never quarantined");
    assert_eq!(m.migrated, 3, "every queued task must be recalled and re-placed");
    assert_eq!(m.completed, 6);
    assert_eq!(m.failed, 0);
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
}

#[test]
fn probe_gated_readmission_end_to_end() {
    let svc = Service::new();
    let ep0 = quick_endpoint(&svc, "rel-probe0", 1);
    let ep1 = quick_endpoint(&svc, "rel-probe1", 2);
    let mut router = Router::new(RouteStrategyKind::LeastLoaded)
        .with_active_probing(true)
        .with_health_config(HealthConfig {
            stall_after: Duration::from_millis(100),
            backoff_base: Duration::from_millis(250),
            backoff_max: Duration::from_secs(2),
            probation: Duration::from_millis(50),
            ..Default::default()
        });
    router.add_target(ep0.id, 0, ep0.probe());
    router.add_target(ep1.id, 1, ep1.probe());
    svc.install_router(router);

    let client = FaasClient::new(svc.clone());
    let echo = svc.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));
    let slow = svc.register_function(
        "blocker",
        Arc::new(|p: &Json, _: &mut _| {
            std::thread::sleep(Duration::from_secs(1));
            Ok(p.clone())
        }),
    );

    // quarantine ep0 via a stall (blocker + backlog), as above
    let blocker = svc.submit(ep0.id, slow, Json::num(0.0)).unwrap();
    wait_running(&svc, blocker);
    let queued: Vec<_> = (0..2).map(|i| svc.submit(ep0.id, echo, patch(i)).unwrap()).collect();
    let t1 = client.run_routed(patch(10), echo).unwrap();
    client.wait(t1, Duration::from_secs(10)).expect("trigger 1");
    std::thread::sleep(Duration::from_millis(200));
    let t2 = client.run_routed(patch(11), echo).unwrap();
    client.wait(t2, Duration::from_secs(10)).expect("trigger 2");
    for id in &queued {
        svc.wait_result(*id, Duration::from_secs(10)).expect("migrated task");
    }
    assert!(svc.metrics.snapshot().endpoints_quarantined >= 1, "setup: no quarantine");

    // keep routed traffic flowing: each submission drives the probe
    // lifecycle (sentence expiry -> synthetic probe -> resolution). The
    // endpoint is back for real only when a routed task lands on it,
    // which active probing forbids until its probe succeeded.
    let t0 = Instant::now();
    let mut landed_on_ep0 = false;
    'outer: while t0.elapsed() < Duration::from_secs(20) {
        let burst: Vec<_> = (0..4).map(|i| client.run_routed(patch(20 + i), echo).unwrap()).collect();
        let placements: Vec<_> = burst.iter().map(|&t| svc.task_endpoint(t)).collect();
        for t in &burst {
            // a burst task may finish (and drop its record) before the
            // placement read above; the read itself raced nothing
            let _ = svc.wait_result(*t, Duration::from_secs(10));
        }
        if placements.iter().any(|p| *p == Some(ep0.id)) {
            landed_on_ep0 = true;
            break 'outer;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    svc.wait_result(blocker, Duration::from_secs(10)).expect("blocker");
    ep0.shutdown();
    ep1.shutdown();

    assert!(landed_on_ep0, "endpoint never rejoined the routing pool after its probe");
    let m = svc.metrics.snapshot();
    assert!(m.health_probes >= 1, "readmission must be probe-gated, not automatic");
    assert!(m.endpoints_readmitted >= 1);
    assert_eq!(m.failed, 0, "{m:?}");
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
}
