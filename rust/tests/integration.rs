//! Integration tests across the full stack: pallet -> patch -> dense model
//! -> AOT artifact execution via PJRT -> CLs, cross-checked against the
//! native-Rust fitter, plus the end-to-end coordinator scan.
//!
//! Requires `make artifacts` (tests are skipped with a notice otherwise).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use pyhf_faas::coordinator::{
    fitops, run_scan, Endpoint, EndpointConfig, ExecutorConfig, FaasClient, ScanOptions, Service,
};
use pyhf_faas::fitter::NativeFitter;
use pyhf_faas::histfactory::{dense, Workspace};
use pyhf_faas::pallet::{self, library};
use pyhf_faas::runtime::{Engine, Manifest};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_covers_all_shape_classes() {
    let Some(dir) = artifact_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for class in ["1Lbb", "2L0J", "stau", "quickstart"] {
        assert!(m.hypotest(class).is_some(), "missing hypotest_{class}");
        assert!(m.mle(class).is_some(), "missing mle_{class}");
    }
    assert_eq!(m.classes().len(), 4);
}

#[test]
fn pjrt_hypotest_matches_native_fitter() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let entry = manifest.hypotest("quickstart").unwrap();
    let compiled = engine.load(entry, &dir).unwrap();

    let pallet = pallet::generate(&library::config_quickstart());
    for patch in pallet.patchset.patches.iter().take(3) {
        let ws_json = patch.apply_to(&pallet.bkg_workspace).unwrap();
        let ws = Workspace::from_json(&ws_json).unwrap();
        let model = dense::compile(&ws, &entry.class).unwrap();

        let pjrt = compiled.hypotest(&model).unwrap();
        let native = NativeFitter::new(&model).hypotest(1.0);

        // Two independent optimizers (CG-Fisher in HLO vs Cholesky-Fisher in
        // Rust) on the same NLL: physics quantities must agree closely.
        assert!(
            (pjrt.cls_obs - native.cls_obs).abs() < 0.02,
            "{}: cls_obs pjrt {} vs native {}",
            patch.name,
            pjrt.cls_obs,
            native.cls_obs
        );
        assert!(
            (pjrt.mu_hat - native.mu_hat).abs() < 0.05 * (1.0 + native.mu_hat.abs()),
            "{}: mu_hat pjrt {} vs native {}",
            patch.name,
            pjrt.mu_hat,
            native.mu_hat
        );
        assert!(
            (pjrt.qmu_a - native.qmu_a).abs() < 0.05 * (1.0 + native.qmu_a),
            "{}: qmu_A pjrt {} vs native {}",
            patch.name,
            pjrt.qmu_a,
            native.qmu_a
        );
        for k in 0..5 {
            assert!(
                (pjrt.cls_exp[k] - native.cls_exp[k]).abs() < 0.02,
                "{}: cls_exp[{k}] pjrt {} vs native {}",
                patch.name,
                pjrt.cls_exp[k],
                native.cls_exp[k]
            );
        }
    }
}

#[test]
fn mle_artifact_agrees_with_native_minimum() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let entry = manifest.mle("quickstart").unwrap();
    let compiled = engine.load(entry, &dir).unwrap();

    let pallet = pallet::generate(&library::config_quickstart());
    let patch = &pallet.patchset.patches[0];
    let ws = Workspace::from_json(&patch.apply_to(&pallet.bkg_workspace).unwrap()).unwrap();
    let model = dense::compile(&ws, &entry.class).unwrap();

    let (theta, nll, diag) = compiled.mle(&model).unwrap();
    assert_eq!(theta.len(), entry.class.n_params());
    assert!(nll.is_finite());
    assert!(diag[0] >= 1.0, "no accepted steps");

    let native = NativeFitter::new(&model).fit_free(&model.data, &pyhf_faas::fitter::Centers::nominal(&model));
    assert!(
        (nll - native.nll).abs() < 1e-3 * (1.0 + native.nll.abs()),
        "nll pjrt {nll} vs native {}",
        native.nll
    );
    assert!((theta[0] - native.theta[0]).abs() < 0.05 * (1.0 + native.theta[0].abs()));
}

#[test]
fn coordinator_scan_pjrt_end_to_end() {
    let Some(dir) = artifact_dir() else { return };
    let svc = Service::new();
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("pjrt-test")
            .with_executor(ExecutorConfig {
                max_blocks: 1,
                nodes_per_block: 1,
                workers_per_node: 1,
                parallelism: 1.0,
                poll: Duration::from_millis(1),
            })
            .with_worker_init(fitops::pjrt_worker_init(dir)),
    );
    let client = FaasClient::new(svc.clone());
    let f = client.register_function("fit_patch", fitops::fit_patch_handler());

    let pallet = pallet::generate(&library::config_quickstart());
    let opts = ScanOptions { limit: Some(3), ..Default::default() };
    let scan = run_scan(&client, ep.id, f, &pallet, &opts).unwrap();

    assert_eq!(scan.points.len(), 3);
    for p in &scan.points {
        assert!(p.cls_obs >= 0.0 && p.cls_obs <= 1.0 + 1e-9);
        assert!(p.qmu_a > 0.0, "{}: degenerate qmu_A", p.patch);
        assert!(p.fit_seconds > 0.0);
    }
    // all tasks accounted (task lifecycle lands on the service metrics;
    // block/worker provisioning lands on the endpoint metrics)
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.completed, 3);
    assert!(snap.mean_service_s > 0.0);
    assert!(ep.metrics_snapshot().blocks_provisioned >= 1);
    ep.shutdown();
}

#[test]
fn oversized_workspace_rejected_cleanly() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    // a 1Lbb-sized pallet cannot compile into the quickstart class
    let pallet = pallet::generate(&library::config_1lbb());
    let ws = Workspace::from_json(&pallet.bkg_workspace).unwrap();
    let entry = manifest.hypotest("quickstart").unwrap();
    let err = dense::compile(&ws, &entry.class).unwrap_err();
    assert!(err.0.contains("bins") || err.0.contains("rows"), "{}", err.0);
    // but pick_class finds the right one
    let classes = manifest.classes();
    let picked = dense::pick_class(&ws, &classes).unwrap();
    assert_eq!(picked.name, "1Lbb");
}

#[test]
fn executable_cache_reused_across_tasks() {
    let Some(dir) = artifact_dir() else { return };
    // two fits through the same worker context must compile only once:
    // second hypotest call should be much faster than the first
    let svc = Service::new();
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("cache-test")
            .with_executor(ExecutorConfig {
                max_blocks: 1,
                nodes_per_block: 1,
                workers_per_node: 1,
                parallelism: 1.0,
                poll: Duration::from_millis(1),
            })
            .with_worker_init(fitops::pjrt_worker_init(dir)),
    );
    let client = FaasClient::new(svc.clone());
    let f = client.register_function("fit_patch", fitops::fit_patch_handler());
    let pallet = pallet::generate(&library::config_quickstart());

    let mut times = Vec::new();
    for patch in pallet.patchset.patches.iter().take(3) {
        let payload = fitops::patch_payload(&pallet.bkg_workspace, patch, None).unwrap();
        let t0 = std::time::Instant::now();
        let id = client.run(payload, ep.id, f).unwrap();
        client.wait(id, Duration::from_secs(300)).unwrap();
        times.push(t0.elapsed().as_secs_f64());
    }
    // first call includes the artifact compile; later ones are cached
    assert!(
        times[2] < times[0],
        "expected cached fit ({}) to beat first fit ({})",
        times[2],
        times[0]
    );
    ep.shutdown();
}
