//! Counting-allocator audit of the fit hot path.
//!
//! The ISSUE-2 acceptance criterion: after warmup, an NLL evaluation
//! through the fused scratch-reuse kernel performs **zero** heap
//! allocations, and a full fit allocates only its `FitResult::theta`
//! vector. This binary installs a counting global allocator (own test
//! target, so the counter sees every allocation in the process) and
//! measures exact allocation deltas around the hot loops.
//!
//! Measurement noise: libtest's coordinator thread may allocate while
//! printing a finished test's result concurrently with the next test's
//! measured region. Each region is therefore measured several times and
//! judged on the *minimum* delta — an allocation intrinsic to the code
//! path shows up in every attempt, scheduler noise does not.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use pyhf_faas::fitter::{nll_batch, simd, Centers, FitScratch, NativeFitter, NllBatch};
use pyhf_faas::histfactory::dense::{self, builtin_class};
use pyhf_faas::histfactory::spec::Workspace;
use pyhf_faas::pallet::{generate, library};
use pyhf_faas::runtime::native_hypotest;

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Serializes the audited regions across the harness's test threads.
static AUDIT: Mutex<()> = Mutex::new(());

/// Minimum allocation count of `f` over several attempts.
fn min_allocs(attempts: usize, mut f: impl FnMut()) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..attempts {
        let before = ALLOCATIONS.load(Ordering::SeqCst);
        f();
        let after = ALLOCATIONS.load(Ordering::SeqCst);
        best = best.min(after - before);
    }
    best
}

fn quickstart_model() -> dense::DenseModel {
    let cfg = library::config_quickstart();
    let pallet = generate(&cfg);
    let patch = &pallet.patchset.patches[0];
    let ws = Workspace::from_json(&patch.apply_to(&pallet.bkg_workspace).unwrap()).unwrap();
    dense::compile(&ws, &builtin_class("quickstart")).unwrap()
}

#[test]
fn nll_evaluation_is_allocation_free_after_warmup() {
    let _guard = AUDIT.lock().unwrap();
    let model = quickstart_model();
    let fitter = NativeFitter::new(&model);
    let centers = Centers::nominal(&model);
    let theta = fitter.init_theta(1.2);
    // warmup: sizes the scratch once
    std::hint::black_box(fitter.nll(&theta, &model.data, &centers));

    let allocs = min_allocs(5, || {
        for _ in 0..256 {
            std::hint::black_box(fitter.nll(&theta, &model.data, &centers));
        }
    });
    assert_eq!(allocs, 0, "NLL evaluations allocated {allocs} times over 256 calls");
}

#[test]
fn nll_evaluation_is_allocation_free_on_every_tier() {
    let _guard = AUDIT.lock().unwrap();
    let initial = simd::active();
    let model = quickstart_model();
    let fitter = NativeFitter::new(&model);
    let centers = Centers::nominal(&model);
    let theta = fitter.init_theta(1.2);
    for t in simd::supported_tiers() {
        simd::force(t).unwrap();
        // warmup: sizes the scratch once (re-sizing is a no-op after)
        std::hint::black_box(fitter.nll(&theta, &model.data, &centers));
        let allocs = min_allocs(5, || {
            for _ in 0..256 {
                std::hint::black_box(fitter.nll(&theta, &model.data, &centers));
            }
        });
        assert_eq!(
            allocs,
            0,
            "tier {}: NLL evaluations allocated {allocs} times over 256 calls",
            t.name()
        );
    }
    simd::force(initial).unwrap();
}

#[test]
fn batched_nll_is_allocation_free_after_warmup_on_every_tier() {
    let _guard = AUDIT.lock().unwrap();
    let initial = simd::active();
    let model = quickstart_model();
    let fitter = NativeFitter::new(&model);
    let centers = Centers::nominal(&model);
    let k = 8;
    let theta = fitter.init_theta(1.2);
    let models: Vec<&dense::DenseModel> = vec![&model; k];
    let thetas: Vec<&[f64]> = vec![&theta[..]; k];
    let datas: Vec<&[f64]> = vec![&model.data[..]; k];
    let center_refs: Vec<&Centers> = vec![&centers; k];
    let mut ws = NllBatch::for_class(&model.class, k);
    let mut out = vec![0.0; k];
    for t in simd::supported_tiers() {
        simd::force(t).unwrap();
        std::hint::black_box(nll_batch(&models, &thetas, &datas, &center_refs, &mut ws, &mut out));
        let allocs = min_allocs(5, || {
            for _ in 0..64 {
                nll_batch(&models, &thetas, &datas, &center_refs, &mut ws, &mut out);
                std::hint::black_box(out[0]);
            }
        });
        assert_eq!(
            allocs,
            0,
            "tier {}: batched NLL sweeps allocated {allocs} times over 64 calls",
            t.name()
        );
    }
    simd::force(initial).unwrap();
}

#[test]
fn full_fit_allocates_only_its_result_vector() {
    let _guard = AUDIT.lock().unwrap();
    let model = quickstart_model();
    let fitter = NativeFitter::new(&model);
    let centers = Centers::nominal(&model);
    // warmup
    std::hint::black_box(fitter.fit_free(&model.data, &centers));

    let fits = 16u64;
    let allocs = min_allocs(5, || {
        for _ in 0..fits {
            std::hint::black_box(fitter.fit_free(&model.data, &centers));
        }
    });
    let per_fit = allocs as f64 / fits as f64;
    // one allocation per fit: the theta0 vector that becomes
    // FitResult::theta (plus nothing else — every intermediate lives in
    // the reused scratch)
    assert!(per_fit <= 2.0, "full fit allocates {per_fit} times per fit (expected <= 2)");
}

#[test]
fn warm_worker_hypotest_reuses_one_scratch_across_calls() {
    let _guard = AUDIT.lock().unwrap();
    let model = quickstart_model();
    let mut scratch = FitScratch::default();
    // warmup sizes the scratch; subsequent hypotests must reuse it
    std::hint::black_box(native_hypotest(&model, &mut scratch, 1.0));

    let allocs = min_allocs(5, || {
        std::hint::black_box(native_hypotest(&model, &mut scratch, 1.0));
    });
    // a full 4-fit hypotest allocates only its per-fit theta vectors, the
    // nominal/Asimov centers and the fixed masks — O(10) small vecs, not
    // O(newton iterations x params) like the seed
    assert!(
        allocs <= 24,
        "warm hypotest allocated {allocs} times (expected <= 24)"
    );
}
