//! End-to-end observability properties: the task ledger balances
//! (`submitted == completed + failed + cancelled`) across routed, batched
//! and gather-cancelled scenarios, and the drained task-lifecycle trace
//! reconciles with that ledger event-for-event.
//!
//! The trace hub is process-global, so every traced test serializes on one
//! lock and clears leftover events before enabling.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use pyhf_faas::coordinator::chaos;
use pyhf_faas::coordinator::{
    ChaosFault, ChaosPlan, ChaosRule, Endpoint, EndpointConfig, ExecutorConfig, FaasClient,
    FaultPoint, HedgePolicy, ReliabilityPolicy, RetryPolicy, Service, ServiceHandle,
};
use pyhf_faas::scheduler::{PolicyKind, RouteStrategyKind, Router, SchedQueue, TaskMeta};
use pyhf_faas::trace::{self, chrome, kind};
use pyhf_faas::util::json::Json;

fn trace_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn quick_endpoint(svc: &ServiceHandle, name: &str, workers: usize) -> Endpoint {
    Endpoint::start(
        svc.clone(),
        EndpointConfig::new(name)
            .with_executor(ExecutorConfig {
                max_blocks: 1,
                nodes_per_block: 1,
                workers_per_node: workers,
                parallelism: 1.0,
                poll: Duration::from_millis(1),
            })
            .with_policy(PolicyKind::Affinity),
    )
}

fn gather_all(client: &FaasClient, tasks: &[pyhf_faas::coordinator::TaskId]) {
    client
        .gather(tasks, Duration::from_secs(10), Duration::from_millis(1), None, |_, _| {})
        .expect("gather");
}

#[test]
fn routed_scan_ledger_balances_and_trace_reconciles() {
    let _g = trace_lock();
    trace::clear();
    trace::enable();

    let svc = Service::new();
    let ep0 = quick_endpoint(&svc, "obs-site0", 2);
    let ep1 = quick_endpoint(&svc, "obs-site1", 2);
    let mut router = Router::new(RouteStrategyKind::WarmFirst);
    router.add_target(ep0.id, 0, ep0.probe());
    router.add_target(ep1.id, 1, ep1.probe());
    svc.install_router(router);

    let client = FaasClient::new(svc.clone());
    let f = client.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));
    let n = 12usize;
    let tasks: Vec<_> = (0..n)
        .map(|i| {
            client
                .run_routed(
                    Json::obj(vec![("n", Json::num(i as f64)), ("class", Json::str("A"))]),
                    f,
                )
                .unwrap()
        })
        .collect();
    gather_all(&client, &tasks);
    ep0.shutdown();
    ep1.shutdown();

    let t = trace::drain();
    trace::disable();

    // ledger: every submission reached exactly one terminal state
    let m = svc.metrics.snapshot();
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
    assert_eq!(m.completed, n as u64);
    assert_eq!(m.cancelled, 0);

    // trace <-> ledger reconciliation
    assert_eq!(t.of_kind(kind::TASK_SUBMIT).len() as u64, m.submitted);
    assert_eq!(t.of_kind(kind::TASK_RESULT).len() as u64, m.completed + m.failed);
    assert_eq!(t.of_kind(kind::TASK_CANCEL).len() as u64, m.cancelled);
    assert_eq!(t.of_kind(kind::ROUTE_DECIDE).len() as u64, m.routed);
    // every executed task carries its wait + execute spans
    assert_eq!(t.of_kind(kind::TASK_WAIT).len(), n);
    assert_eq!(t.of_kind(kind::TASK_EXECUTE).len(), n);
    assert!(!t.of_kind(kind::WORKER_STARTUP).is_empty(), "no worker startup span");
    assert!(!t.of_kind(kind::CLIENT_GATHER).is_empty(), "no client gather span");
    // spans nest: each execute starts no earlier than its wait ends
    for e in t.of_kind(kind::TASK_EXECUTE) {
        let id = e.task.expect("execute span without a task");
        let wait = t
            .of_kind(kind::TASK_WAIT)
            .into_iter()
            .find(|w| w.task == Some(id))
            .expect("execute without wait");
        assert!(wait.ts_us + wait.dur_us <= e.ts_us + 1_000, "wait overlaps execute");
    }
    // the whole thing exports as a valid Chrome trace document
    chrome::validate(&chrome::chrome_doc(&t)).expect("trace doc must validate");
}

#[test]
fn batched_wave_ledger_balances_and_enqueues_are_traced() {
    let _g = trace_lock();
    trace::clear();
    trace::enable();

    let svc = Service::new();
    let ep = quick_endpoint(&svc, "obs-batch", 2);
    let client = FaasClient::new(svc.clone());
    let f = client.register_function(
        "echo",
        pyhf_faas::scheduler::batched_handler(Arc::new(|p: &Json, _| Ok(p.clone()))),
    );
    let mk = |name: &str, class: &str| {
        Json::obj(vec![("patch", Json::str(name)), ("class", Json::str(class))])
    };
    let payloads =
        vec![mk("a0", "A"), mk("b0", "B"), mk("a0", "A"), mk("a1", "A"), mk("b1", "B")];
    let sub = client.run_coalesced(&payloads, ep.id, f, 4).unwrap();
    let n_groups = sub.tasks.len();
    assert_eq!(n_groups, 2, "4 uniques -> one A-batch + one B-batch");
    gather_all(&client, &sub.tasks);
    ep.shutdown();

    let t = trace::drain();
    trace::disable();

    let m = svc.metrics.snapshot();
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
    assert_eq!(m.completed, n_groups as u64);
    assert_eq!(t.of_kind(kind::TASK_SUBMIT).len(), n_groups);
    assert_eq!(t.of_kind(kind::TASK_ENQUEUE).len(), n_groups);
    assert_eq!(t.of_kind(kind::TASK_RESULT).len(), n_groups);
    assert_eq!(t.of_kind(kind::TASK_EXECUTE).len(), n_groups);
    chrome::validate(&chrome::chrome_doc(&t)).expect("trace doc must validate");
}

#[test]
fn cancelled_gather_ledger_balances_and_cancels_are_traced() {
    let _g = trace_lock();
    trace::clear();
    trace::enable();

    let svc = Service::new();
    let ep = quick_endpoint(&svc, "obs-cancel", 1);
    let client = FaasClient::new(svc.clone());
    let f = svc.register_function(
        "slow",
        Arc::new(|p: &Json, _: &mut _| {
            std::thread::sleep(Duration::from_millis(200));
            Ok(p.clone())
        }),
    );
    let tasks =
        client.run_batch((0..6).map(|i| Json::num(i as f64)).collect(), ep.id, f).unwrap();
    let err = client
        .gather(&tasks, Duration::from_millis(100), Duration::from_millis(2), None, |_, _| {})
        .unwrap_err();
    assert!(err.contains("cancelled"), "{err}");

    // let the abandoned in-flight task finish (its record is dropped on
    // completion) so the trace holds its execute span before we drain
    let t0 = std::time::Instant::now();
    while tasks.iter().any(|id| svc.task_state(*id).is_some()) {
        assert!(t0.elapsed() < Duration::from_secs(5), "task records leaked");
        std::thread::sleep(Duration::from_millis(5));
    }
    ep.shutdown();

    let t = trace::drain();
    trace::disable();

    let m = svc.metrics.snapshot();
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
    assert_eq!(m.submitted, 6);
    assert!(m.cancelled >= 1, "timeout must cancel outstanding work");

    // reconciliation: results only for tasks that completed un-abandoned,
    // one cancel instant per cancelled task, and the abandoned running
    // task still shows its execute span (work happened, result dropped)
    assert_eq!(t.of_kind(kind::TASK_SUBMIT).len() as u64, m.submitted);
    assert_eq!(t.of_kind(kind::TASK_RESULT).len() as u64, m.completed + m.failed);
    assert_eq!(t.of_kind(kind::TASK_CANCEL).len() as u64, m.cancelled);
    assert!(
        t.of_kind(kind::TASK_EXECUTE).len() as u64 >= m.completed,
        "execute spans must cover at least the completed tasks"
    );
    chrome::validate(&chrome::chrome_doc(&t)).expect("trace doc must validate");
}

/// The reliability layer multiplies physical tasks (retries, hedges) and
/// cancels losers, yet the ledger and the trace must still reconcile:
/// every physical submission reaches exactly one terminal bucket, hedged
/// duplicates resolve to one outcome per logical task, and a gather that
/// times out cancels its outstanding work without ever retrying or
/// hedging the tasks it just cancelled.
#[test]
fn reliable_gather_reconciles_hedges_and_cancels() {
    let _g = trace_lock();
    chaos::clear();
    trace::clear();
    trace::enable();

    let svc = Service::new();
    let ep0 = quick_endpoint(&svc, "obs-rel0", 2);
    let ep1 = quick_endpoint(&svc, "obs-rel1", 2);
    let mut router = Router::new(RouteStrategyKind::LeastLoaded);
    router.add_target(ep0.id, 0, ep0.probe());
    router.add_target(ep1.id, 1, ep1.probe());
    svc.install_router(router);

    let client = FaasClient::new(svc.clone()).with_reliability(
        ReliabilityPolicy::new()
            .with_retry(RetryPolicy::with_retries(2))
            .with_hedge(HedgePolicy {
                after_p99: 2.0,
                min_observations: 20,
                min_age: Duration::from_millis(250),
            }),
    );
    let echo = client.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));
    let slow = client.register_function(
        "slow",
        Arc::new(|p: &Json, _: &mut _| {
            std::thread::sleep(Duration::from_millis(300));
            Ok(p.clone())
        }),
    );
    let mk = |i: usize| Json::obj(vec![("n", Json::num(i as f64)), ("class", Json::str("A"))]);

    // phase 1: a clean wave warms the p99 sketch past min_observations
    let warmup: Vec<_> = (0..30).map(|i| client.run_routed(mk(i), echo).unwrap()).collect();
    gather_all(&client, &warmup);

    // phase 2: lose one result; the straggler is rescued by its hedge and
    // the logical task still resolves to exactly one Ok
    chaos::install(ChaosPlan::new(0x0b5).rule(ChaosRule::new(ChaosFault::DropResult, None, 0, 1)));
    let stuck = client.run_routed(mk(100), echo).unwrap();
    let rescued = client
        .gather(&[stuck], Duration::from_secs(20), Duration::from_millis(2), None, |_, _| {})
        .expect("gather");
    let plan = chaos::clear().expect("plan still installed");
    assert_eq!(plan.total_hits(), 1);
    assert!(rescued[0].is_ok(), "hedge must rescue the lost result: {:?}", rescued[0]);

    // phase 3: a gather that times out cancels its outstanding tasks —
    // and those cancellations must not feed back into retry or hedging
    let doomed: Vec<_> = (0..6).map(|i| client.run_routed(mk(200 + i), slow).unwrap()).collect();
    let err = client
        .gather(&doomed, Duration::from_millis(100), Duration::from_millis(2), None, |_, _| {})
        .unwrap_err();
    assert!(err.contains("cancelled"), "{err}");

    // abandoned in-flight tasks drain when their handler returns; only
    // the chaos-stuck primary (whose completion was dropped) may remain
    let t0 = Instant::now();
    while doomed.iter().any(|id| svc.task_state(*id).is_some()) {
        assert!(t0.elapsed() < Duration::from_secs(5), "cancelled task records leaked");
        std::thread::sleep(Duration::from_millis(5));
    }
    ep0.shutdown();
    ep1.shutdown();

    let t = trace::drain();
    trace::disable();

    let m = svc.metrics.snapshot();
    assert_eq!(m.submitted, m.completed + m.failed + m.cancelled);
    assert!(m.hedges >= 1, "the straggler was never hedged");
    assert!(m.hedge_wins >= 1);
    assert_eq!(m.retries, 0, "nothing failed, so nothing may be retried — least of all cancels");
    // the hedge-phase primary plus the six timed-out tasks
    assert!(m.cancelled >= 7, "cancelled {} < 7", m.cancelled);

    // trace <-> ledger reconciliation with duplicates in play: every
    // physical submission traces once, every ledger-counted terminal
    // outcome traces once, every cancel traces once
    assert_eq!(t.of_kind(kind::TASK_SUBMIT).len() as u64, m.submitted);
    assert_eq!(t.of_kind(kind::TASK_RESULT).len() as u64, m.completed + m.failed);
    assert_eq!(t.of_kind(kind::TASK_CANCEL).len() as u64, m.cancelled);
    assert_eq!(t.of_kind(kind::TASK_HEDGE).len() as u64, m.hedges);
    assert_eq!(t.of_kind(kind::TASK_RETRY).len() as u64, m.retries);
    assert_eq!(t.of_kind(kind::ROUTE_DECIDE).len() as u64, m.routed);
    chrome::validate(&chrome::chrome_doc(&t)).expect("trace doc must validate");
}

#[test]
fn disabled_tracing_emits_nothing_through_a_live_scan() {
    let _g = trace_lock();
    trace::clear();
    assert!(!trace::enabled());

    let svc = Service::new();
    let ep = quick_endpoint(&svc, "obs-off", 2);
    let client = FaasClient::new(svc.clone());
    let f = client.register_function("echo", Arc::new(|p: &Json, _: &mut _| Ok(p.clone())));
    let tasks =
        client.run_batch((0..8).map(|i| Json::num(i as f64)).collect(), ep.id, f).unwrap();
    gather_all(&client, &tasks);
    ep.shutdown();

    let t = trace::drain();
    assert!(t.events.is_empty(), "disabled hub buffered {} events", t.events.len());
    assert_eq!(svc.metrics.snapshot().completed, 8);
}

/// Regression for the queue-lock scope fix: `push_meta` now emits its
/// `task.enqueue` instant *after* releasing the interchange guard. The
/// restructure must not lose the event — one enqueue, one instant, with
/// the task id and the routing metadata in the detail.
#[test]
fn enqueue_still_traced_after_guard_release() {
    let _g = trace_lock();
    trace::clear();
    trace::enable();

    let q = SchedQueue::new();
    assert!(q.push_meta(TaskMeta { priority: 2.0, weight: 3, ..TaskMeta::bare(41) }));
    assert_eq!(q.pop(Duration::from_millis(5)), Some(41));

    trace::disable();
    let t = trace::drain();
    let enq = t.of_kind(kind::TASK_ENQUEUE);
    assert_eq!(enq.len(), 1, "exactly one enqueue instant: {enq:?}");
    assert_eq!(enq[0].task, Some(41));
    assert_eq!(enq[0].track, "queue");
    assert!(enq[0].detail.contains("priority 2"), "detail: {}", enq[0].detail);
    assert!(enq[0].detail.contains("weight 3"), "detail: {}", enq[0].detail);
}

/// Regression for the chaos-lock scope fix: `inject` resolves the firing
/// rule under the slot lock but emits `chaos.inject` only after the
/// guard drops. The restructure must not lose the instant — a firing
/// rule still returns the fault AND traces it; a non-firing consult
/// traces nothing.
#[test]
fn chaos_inject_still_traced_after_guard_release() {
    let _g = trace_lock();
    trace::clear();
    trace::enable();

    chaos::install(ChaosPlan::new(9).rule(ChaosRule::new(ChaosFault::Crash, None, 0, 1)));
    assert_eq!(chaos::inject(FaultPoint::Execute, 0, Some(5)), Some(ChaosFault::Crash));
    // the single-hit rule is spent: no fault, and no phantom trace event
    assert_eq!(chaos::inject(FaultPoint::Execute, 0, Some(6)), None);
    let plan = chaos::clear().expect("plan was installed");
    assert_eq!(plan.total_hits(), 1);

    trace::disable();
    let t = trace::drain();
    let inj = t.of_kind(kind::CHAOS_INJECT);
    assert_eq!(inj.len(), 1, "exactly one inject instant: {inj:?}");
    assert_eq!(inj[0].task, Some(5));
    assert!(inj[0].detail.contains("crash at execute"), "detail: {}", inj[0].detail);
}
