//! Hand-rolled line lexer — the crate is dependency-free (no `syn`), so
//! the structural passes work on a cleaned view of the source instead of
//! an AST: per line, the code text with comments removed and string/char
//! literal *contents* blanked (the delimiting quotes stay, so brace
//! counting and pattern matching never trip on literals), plus the
//! comment text collected separately (the `lint:allow` directives and
//! `SAFETY:` justifications live there).
//!
//! Handled: line comments, nested block comments, string literals with
//! escapes, raw strings (`r"…"`, `r#"…"#`), byte strings, and the
//! char-literal-vs-lifetime ambiguity (`'a'` vs `'a`).

/// Per-line cleaned view of one source file. `code.len() == comment.len()`
/// and both are indexed by 0-based line number.
pub struct Cleaned {
    pub code: Vec<String>,
    pub comment: Vec<String>,
}

fn flush(code: &mut Vec<String>, comment: &mut Vec<String>, cc: &mut String, cm: &mut String) {
    code.push(std::mem::take(cc));
    comment.push(std::mem::take(cm));
}

pub fn clean(src: &str) -> Cleaned {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code = Vec::new();
    let mut comment = Vec::new();
    let mut cc = String::new();
    let mut cm = String::new();
    #[derive(PartialEq)]
    enum State {
        Normal,
        LineComment,
        BlockComment,
    }
    let mut state = State::Normal;
    let mut block_depth = 0usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            flush(&mut code, &mut comment, &mut cc, &mut cm);
            if state == State::LineComment {
                state = State::Normal;
            }
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    i += 2;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment;
                    block_depth = 1;
                    i += 2;
                    continue;
                }
                if c == '"' {
                    cc.push('"');
                    i += 1;
                    while i < n && chars[i] != '"' {
                        if chars[i] == '\\' {
                            i += 2;
                            continue;
                        }
                        if chars[i] == '\n' {
                            flush(&mut code, &mut comment, &mut cc, &mut cm);
                        }
                        i += 1;
                    }
                    if i < n {
                        cc.push('"');
                        i += 1;
                    }
                    continue;
                }
                if c == 'r' && matches!(chars.get(i + 1), Some('#') | Some('"')) {
                    // raw string r"…" / r#"…"# — scan to the matching close
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && chars[j] == '#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && chars[j] == '"' {
                        cc.push_str("r\"");
                        j += 1;
                        while j < n {
                            if chars[j] == '"' && (1..=h).all(|k| chars.get(j + k) == Some(&'#'))
                            {
                                j += 1 + h;
                                break;
                            }
                            if chars[j] == '\n' {
                                flush(&mut code, &mut comment, &mut cc, &mut cm);
                            }
                            j += 1;
                        }
                        cc.push('"');
                        i = j;
                        continue;
                    }
                    cc.push(c);
                    i += 1;
                    continue;
                }
                if c == 'b' && chars.get(i + 1) == Some(&'"') {
                    // byte string: emit the `b`, let the quote arm handle it
                    cc.push('b');
                    i += 1;
                    continue;
                }
                if c == '\'' {
                    if chars.get(i + 1) == Some(&'\\') {
                        // escaped char literal '\n' / '\u{…}'
                        let mut j = i + 2;
                        while j < n && chars[j] != '\'' {
                            j += 1;
                        }
                        cc.push_str("' '");
                        i = j + 1;
                        continue;
                    }
                    if chars.get(i + 2) == Some(&'\'') {
                        // plain char literal 'x'
                        cc.push_str("' '");
                        i += 3;
                        continue;
                    }
                    // lifetime 'a — copy through
                    cc.push('\'');
                    i += 1;
                    continue;
                }
                cc.push(c);
                i += 1;
            }
            State::LineComment => {
                cm.push(c);
                i += 1;
            }
            State::BlockComment => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    block_depth += 1;
                    i += 2;
                    continue;
                }
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    block_depth -= 1;
                    i += 2;
                    if block_depth == 0 {
                        state = State::Normal;
                    }
                    continue;
                }
                cm.push(c);
                i += 1;
            }
        }
    }
    flush(&mut code, &mut comment, &mut cc, &mut cm);
    Cleaned { code, comment }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_are_blanked_comments_stripped() {
        let c = clean("let x = \"a { b } c\"; // note { brace }\n");
        assert_eq!(c.code[0], "let x = \"\"; ");
        assert_eq!(c.comment[0], " note { brace }");
    }

    #[test]
    fn nested_block_comments() {
        let c = clean("a /* one /* two */ still */ b\n");
        assert_eq!(c.code[0].split_whitespace().collect::<Vec<_>>(), vec!["a", "b"]);
    }

    #[test]
    fn raw_strings_and_hashes() {
        let c = clean("let s = r#\"has \"quotes\" and { }\"#; done\n");
        assert_eq!(c.code[0], "let s = r\"\"; done");
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let c = clean("fn f<'a>(x: &'a str) { let q = '{'; let e = '\\n'; }\n");
        assert!(c.code[0].contains("<'a>"));
        assert!(!c.code[0].contains("'{'"), "char literal must be blanked: {}", c.code[0]);
        // the blanked '{' must not skew brace depth
        let opens = c.code[0].matches('{').count();
        let closes = c.code[0].matches('}').count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn multiline_string_keeps_line_numbers() {
        let c = clean("let s = \"one\ntwo\nthree\";\nafter\n");
        assert_eq!(c.code.len(), 5);
        assert_eq!(c.code[3], "after");
    }
}
