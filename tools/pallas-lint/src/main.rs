//! CLI: `pallas-lint [--root <repo-root>]`. Prints findings as
//! `file:line: [rule] message`; exit 0 when clean, 1 on findings, 2 on
//! I/O trouble (missing tree). CI runs this as a blocking job.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("pallas-lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: pallas-lint [--root <repo-root>]");
                println!("checks rust/src/** against the invariants in docs/ANALYSIS.md");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("pallas-lint: unknown argument '{other}'");
                return ExitCode::from(2);
            }
        }
    }
    // running from the workspace root or from tools/pallas-lint both work
    if !root.join("rust/src").is_dir() && root.join("../../rust/src").is_dir() {
        root = root.join("../..");
    }
    match pallas_lint::lint_tree(&root) {
        Ok(findings) => {
            for f in &findings {
                println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
            }
            if findings.is_empty() {
                println!("pallas-lint: clean");
                ExitCode::SUCCESS
            } else {
                println!("pallas-lint: {} finding(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("pallas-lint: cannot read tree under {}: {e}", root.display());
            ExitCode::from(2)
        }
    }
}
