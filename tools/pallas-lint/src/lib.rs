//! pallas-lint — machine-checked invariants for the serving fabric.
//!
//! Rules (catalogue and rationale in docs/ANALYSIS.md):
//! * `lock_order` / `lock_scope` — every lock acquisition resolves to a
//!   named domain; nestings must be in the declared partial order; guards
//!   must not span calls into other locking modules.
//! * `no_panic` — no unwrap/expect/panic-family sites in coordinator/,
//!   scheduler/, trace/ non-test code.
//! * `probe_gate` — trace/chaos/logging fast-path gates are a single
//!   relaxed atomic load, lock- and allocation-free.
//! * `safety_comment` — every `unsafe` carries a `// SAFETY:` note.
//! * `registry_sync` — metrics counters, trace kinds, and typed error
//!   codes stay in lockstep with their exporters and docs.
//!
//! Suppression: `// lint:allow(<rule>) <reason>` on the offending line or
//! in the comment block directly above it.

pub mod engine;
pub mod lexer;
pub mod registry;
pub mod rules;

pub use engine::Finding;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> =
        fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint the whole tree under `root` (the repo root): every file in
/// `rust/src/**` through the per-file rules, then the registry_sync
/// cross-file checks. Shared by the binary and the
/// `real_tree_is_clean` integration test.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    let mut files = Vec::new();
    collect_rs(&root.join("rust/src"), &mut files)?;
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(path)?;
        findings.extend(rules::lint_source(&rel, &src));
    }

    let read = |rel: &str| fs::read_to_string(root.join(rel));
    let metrics = read("rust/src/coordinator/metrics.rs")?;
    let metricsjson = read("rust/src/bench/metricsjson.rs")?;
    let benchmarks_doc = read("docs/BENCHMARKS.md")?;
    let trace_mod = read("rust/src/trace/mod.rs")?;
    let chrome = read("rust/src/trace/chrome.rs")?;
    let reliability = read("rust/src/coordinator/reliability.rs")?;
    let journal = read("rust/src/coordinator/journal.rs")?;
    let reliability_doc = read("docs/RELIABILITY.md")?;
    findings.extend(registry::check_registry(&registry::RegistryInputs {
        metrics: &metrics,
        metricsjson: &metricsjson,
        benchmarks_doc: &benchmarks_doc,
        trace_mod: &trace_mod,
        chrome: &chrome,
        reliability: &reliability,
        journal: &journal,
        reliability_doc: &reliability_doc,
    }));
    Ok(findings)
}
