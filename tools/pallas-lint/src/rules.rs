//! The per-file rules: `no_panic`, `lock_order` / `lock_scope`,
//! `probe_gate`, `safety_comment`. Every rule reports [`Finding`]s that
//! the `lint:allow(<rule>) reason` directive can suppress (see
//! docs/ANALYSIS.md for the catalogue and the allowlist policy).

use crate::engine::{depth_map, is_allowed, test_ranges, Finding};
use crate::lexer::{clean, Cleaned};

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

// ---------------------------------------------------------------------------
// rule: no_panic — no panic sites on coordinator/scheduler/trace hot paths
// ---------------------------------------------------------------------------

const NO_PANIC_SCOPES: &[&str] = &["coordinator/", "scheduler/", "trace/"];
const PANIC_MACROS: &[&str] = &["panic!", "unreachable!", "todo!", "unimplemented!"];

/// `mac` occurring as a macro invocation: preceded by a non-identifier
/// char and followed by an opening delimiter.
fn macro_invocation(line: &str, mac: &str) -> bool {
    for (pos, _) in line.match_indices(mac) {
        let boundary = pos == 0
            || !line[..pos].chars().next_back().map(is_ident).unwrap_or(false);
        let after = line[pos + mac.len()..].trim_start();
        if boundary && matches!(after.chars().next(), Some('(' | '[' | '{')) {
            return true;
        }
    }
    false
}

fn rule_no_panic(rel: &str, c: &Cleaned, tests: &[bool], out: &mut Vec<Finding>) {
    if !NO_PANIC_SCOPES.iter().any(|s| rel.contains(s)) {
        return;
    }
    for (i, line) in c.code.iter().enumerate() {
        if tests[i] {
            continue;
        }
        let mut hits: Vec<&str> = Vec::new();
        if line.contains(".unwrap()") {
            hits.push("unwrap() on a hot path");
        }
        if line.contains(".expect(") {
            hits.push("expect() on a hot path");
        }
        for mac in PANIC_MACROS {
            if macro_invocation(line, mac) {
                hits.push("panic-family macro on a hot path");
            }
        }
        for msg in hits {
            if !is_allowed(c, i, "no_panic") {
                out.push(Finding::new(rel, i, "no_panic", format!(
                    "{msg} — return a typed error, degrade gracefully, or justify \
                     with `// lint:allow(no_panic) <reason>`"
                )));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: lock_order / lock_scope — the declared locking discipline
// ---------------------------------------------------------------------------

/// The domain table: every `Mutex`/`RwLock` acquisition in the scanned
/// files must resolve to a named domain by its receiver expression.
/// An acquisition that matches no entry is itself a finding — adding a
/// lock to these modules forces a table (and docs/ANALYSIS.md) update.
const DOMAINS: &[(&str, &[(&str, &str)])] = &[
    ("coordinator/service.rs", &[
        ("self.state", "state"),
        ("self.router", "router"),
        ("self.journal", "journal_slot"),
    ]),
    ("coordinator/metrics.rs", &[("self.inner", "metrics")]),
    ("coordinator/journal.rs", &[("self.inner", "journal")]),
    ("coordinator/client.rs", &[(".specs", "client_specs")]),
    ("coordinator/chaos.rs", &[("slot()", "chaos")]),
    ("coordinator/executor.rs", &[("blocks_list", "executor_blocks")]),
    ("scheduler/queue.rs", &[("self.inner", "queue")]),
    ("scheduler/router.rs", &[]),
    ("trace/mod.rs", &[
        ("registry()", "trace_registry"),
        ("buf", "trace_buffer"),
    ]),
    ("util/logging.rs", &[
        ("self.records", "logging_records"),
        ("sink_slot()", "logging_sink"),
    ]),
    ("util/threadpool.rs", &[("rx", "threadpool")]),
];

/// The declared partial order: the only nestings allowed to exist.
/// Everything else — including a domain nested under itself — is a
/// `lock_order` violation.
const ALLOWED_NESTINGS: &[(&str, &str)] = &[
    // health events are counted under the router guard (one narrow
    // metrics bump; metrics never calls back out)
    ("router", "metrics"),
    // the trace drain walks per-thread buffers under the registry guard
    ("trace_registry", "trace_buffer"),
];

/// Calls that acquire a domain internally. A guard whose range contains
/// one of these spans a call into another locking module (`lock_scope`).
/// `home` exempts the module that *implements* the callee.
const CALLEES: &[(&str, &str, Option<&str>)] = &[
    ("crate::trace::instant", "trace_buffer", Some("trace/mod.rs")),
    ("trace::instant(", "trace_buffer", Some("trace/mod.rs")),
    ("crate::trace::span", "trace_buffer", Some("trace/mod.rs")),
    ("trace::span(", "trace_buffer", Some("trace/mod.rs")),
    ("trace::span_at(", "trace_buffer", Some("trace/mod.rs")),
    ("trace::span_between(", "trace_buffer", Some("trace/mod.rs")),
    ("trace::export", "trace_export", Some("trace/mod.rs")),
    ("trace::drain(", "trace_export", Some("trace/mod.rs")),
    ("self.metrics.", "metrics", Some("coordinator/metrics.rs")),
    ("journal_record(", "journal", None),
    ("journal_handle()", "journal_slot", Some("coordinator/service.rs")),
    ("append(journal::Record", "journal", Some("coordinator/journal.rs")),
    (".sync()", "journal_sync", Some("coordinator/journal.rs")),
    ("push_meta(", "queue", Some("scheduler/queue.rs")),
    ("pop_task(", "queue", Some("scheduler/queue.rs")),
    (".discard(", "queue", Some("scheduler/queue.rs")),
    ("recall_queued(", "queue", Some("scheduler/queue.rs")),
    ("drain_remaining(", "queue", Some("scheduler/queue.rs")),
    ("queued_weight()", "queue", Some("scheduler/queue.rs")),
    ("oldest_wait()", "queue", Some("scheduler/queue.rs")),
    ("q.len()", "queue", Some("scheduler/queue.rs")),
    ("queue.len()", "queue", Some("scheduler/queue.rs")),
    ("endpoint_label(", "state", Some("coordinator/service.rs")),
    ("expire_task(", "state", Some("coordinator/service.rs")),
    ("chaos::inject(", "chaos", Some("coordinator/chaos.rs")),
    ("log_debug!", "logging_sink", Some("util/logging.rs")),
    ("log_info!", "logging_sink", Some("util/logging.rs")),
    ("log_warn!", "logging_sink", Some("util/logging.rs")),
    ("log_error!", "logging_sink", Some("util/logging.rs")),
];

const ACQS: &[&str] = &[".lock_unpoisoned()", ".lock()", ".read()", ".write()"];

/// The once-init lock inside static-slot helpers (`slot()`,
/// `registry()`): held only during first-use initialization and released
/// before the helper returns, so it never overlaps a domain guard.
const INIT_RECEIVERS: &[&str] = &["LOCK"];

struct Acq {
    line: usize,
    domain: &'static str,
}

fn domain_table(rel: &str) -> Option<&'static [(&'static str, &'static str)]> {
    DOMAINS.iter().find(|(suf, _)| rel.ends_with(suf)).map(|(_, t)| *t)
}

fn find_acquisitions(
    rel: &str,
    c: &Cleaned,
    tests: &[bool],
    out: &mut Vec<Finding>,
) -> Vec<Acq> {
    let Some(table) = domain_table(rel) else { return Vec::new() };
    let mut acqs = Vec::new();
    for i in 0..c.code.len() {
        if tests[i] {
            continue;
        }
        for suf in ACQS {
            for (pos, _) in c.code[i].match_indices(suf) {
                // the receiver may continue from previous lines when the
                // chain is rustfmt-broken (`self\n  .state\n  .lock…()`)
                let mut prefix = c.code[i][..pos].to_string();
                let mut k = i;
                while (prefix.trim().is_empty() || prefix.trim().starts_with('.')) && k > 0 {
                    k -= 1;
                    prefix = format!("{}{}", c.code[k], prefix);
                }
                let recv: String = prefix.chars().filter(|ch| !ch.is_whitespace()).collect();
                if INIT_RECEIVERS.iter().any(|x| recv.ends_with(x)) {
                    continue;
                }
                match table.iter().find(|(pat, _)| recv.ends_with(pat)) {
                    Some((_, dom)) => acqs.push(Acq { line: i, domain: dom }),
                    None => {
                        let tail: String = recv
                            .chars()
                            .rev()
                            .take(40)
                            .collect::<String>()
                            .chars()
                            .rev()
                            .collect();
                        out.push(Finding::new(rel, i, "lock_order", format!(
                            "unregistered lock acquisition (receiver '…{tail}') — add it \
                             to the pallas-lint domain table and docs/ANALYSIS.md"
                        )));
                    }
                }
            }
        }
    }
    acqs
}

/// `let g = recv.lock…();` — a named guard binding; returns the ident.
fn let_guard_ident(line: &str) -> Option<String> {
    let t = line.trim();
    let rest = t.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let ident: String = rest.chars().take_while(|ch| is_ident(*ch)).collect();
    if ident.is_empty() {
        return None;
    }
    if !rest[ident.len()..].trim_start().starts_with('=') {
        return None;
    }
    let closes_stmt = ACQS.iter().any(|s| {
        let mut pat = String::from(*s);
        pat.push(';');
        t.ends_with(&pat)
    });
    if closes_stmt {
        Some(ident)
    } else {
        None
    }
}

/// The inclusive line range a guard acquired on `line` is considered
/// held, by statement shape:
/// * named guard (`let g = ….lock…();`) — until `drop(g)` or the end of
///   the enclosing block;
/// * `if let` / `while let` / `match` / `let … else` head — the
///   construct's block (temporaries live for the whole construct);
/// * expression temporary — until the statement ends.
fn guard_range(code: &[String], depth: &[i32], line: usize) -> (usize, usize) {
    let n = code.len();
    if let Some(g) = let_guard_ident(&code[line]) {
        let d0 = depth[line];
        let needle = format!("drop({g})");
        let mut j = line + 1;
        while j < n {
            if code[j].contains(&needle) {
                return (line, j);
            }
            if depth[j] < d0 {
                return (line, j - 1);
            }
            j += 1;
        }
        return (line, n - 1);
    }
    let t = code[line].trim();
    if t.starts_with("if let")
        || t.starts_with("while let")
        || t.starts_with("match ")
        || code[line].contains(" else {")
    {
        let d0 = depth[line];
        let mut j = line + 1;
        while j < n && depth[j] > d0 {
            j += 1;
        }
        return (line, if j > line + 1 { j - 1 } else { line });
    }
    let mut j = line;
    while j < n {
        if j > line && depth[j] < depth[line] {
            return (line, j - 1);
        }
        let t = code[j].trim_end();
        if t.ends_with(';') || t.ends_with('{') || t.ends_with('}') {
            return (line, j);
        }
        j += 1;
    }
    (line, line)
}

fn rule_lock(rel: &str, c: &Cleaned, tests: &[bool], depth: &[i32], out: &mut Vec<Finding>) {
    let acqs = find_acquisitions(rel, c, tests, out);
    for acq in &acqs {
        let (start, end) = guard_range(&c.code, depth, acq.line);
        for j in start..=end {
            // nested acquisition inside the guard range
            for other in acqs.iter().filter(|a| a.line == j) {
                if j == acq.line && other.domain == acq.domain {
                    continue;
                }
                if ALLOWED_NESTINGS.contains(&(acq.domain, other.domain)) {
                    continue;
                }
                if is_allowed(c, j, "lock_order") {
                    continue;
                }
                out.push(Finding::new(rel, j, "lock_order", format!(
                    "acquires '{}' while holding '{}' (guard from line {}) — not in \
                     the declared lock order",
                    other.domain,
                    acq.domain,
                    acq.line + 1
                )));
            }
            // call into another locking module while the guard is held;
            // first matching pattern wins (overlapping patterns like
            // `crate::trace::instant` / `trace::instant(` describe the
            // same call and must yield one finding)
            for (pat, callee_dom, home) in CALLEES {
                if !c.code[j].contains(pat) {
                    continue;
                }
                let home_exempt = home.map(|h| rel.ends_with(h)).unwrap_or(false);
                if !home_exempt
                    && *callee_dom != acq.domain
                    && !ALLOWED_NESTINGS.contains(&(acq.domain, callee_dom))
                    && !is_allowed(c, j, "lock_scope")
                {
                    out.push(Finding::new(rel, j, "lock_scope", format!(
                        "'{}' guard (line {}) spans a call into '{}' ({}) — release \
                         the guard first, or justify with `// lint:allow(lock_scope)`",
                        acq.domain,
                        acq.line + 1,
                        callee_dom,
                        pat.trim_end_matches('(')
                    )));
                }
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: probe_gate — disabled-path gates are one relaxed atomic load
// ---------------------------------------------------------------------------

const PROBE_FNS: &[(&str, &str)] = &[
    ("trace/mod.rs", "pub fn enabled"),
    ("coordinator/chaos.rs", "pub fn active"),
    ("util/logging.rs", "pub fn enabled"),
    ("fitter/simd/mod.rs", "pub fn active"),
];
const PROBE_FORBIDDEN: &[&str] =
    &[".lock", "format!", "to_string", "String::", "Vec::", "Box::", ".clone()"];

fn rule_probe_gate(rel: &str, c: &Cleaned, tests: &[bool], out: &mut Vec<Finding>) {
    for (suffix, sig) in PROBE_FNS {
        if !rel.ends_with(suffix) {
            continue;
        }
        let n = c.code.len();
        for i in 0..n {
            if tests[i] || !c.code[i].contains(sig) {
                continue;
            }
            // collect the fn body: sig line through its matching close
            let mut d = 0i32;
            let mut opened = false;
            let mut body: Vec<usize> = Vec::new();
            let mut j = i;
            while j < n {
                let opens = c.code[j].matches('{').count() as i32;
                let closes = c.code[j].matches('}').count() as i32;
                d += opens - closes;
                if opens > 0 {
                    opened = true;
                }
                if j > i || opens > 0 {
                    body.push(j);
                }
                if opened && d <= 0 {
                    break;
                }
                j += 1;
            }
            let has_load = body.iter().any(|&j| c.code[j].contains("load(Ordering::Relaxed)"));
            if !has_load && !is_allowed(c, i, "probe_gate") {
                out.push(Finding::new(rel, i, "probe_gate", format!(
                    "{sig}(): fast-path gate must be a single relaxed atomic load"
                )));
            }
            for &j in &body {
                for f in PROBE_FORBIDDEN {
                    if c.code[j].contains(f) && !is_allowed(c, j, "probe_gate") {
                        out.push(Finding::new(rel, j, "probe_gate", format!(
                            "{sig}(): '{f}' in a fast-path gate (must be lock- and \
                             allocation-free when disabled)"
                        )));
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule: safety_comment — every `unsafe` carries a // SAFETY: justification
// ---------------------------------------------------------------------------

fn rule_safety(rel: &str, c: &Cleaned, tests: &[bool], out: &mut Vec<Finding>) {
    for (i, line) in c.code.iter().enumerate() {
        if tests[i] {
            continue;
        }
        let has_unsafe = line.match_indices("unsafe").any(|(pos, _)| {
            let before_ok =
                pos == 0 || !line[..pos].chars().next_back().map(is_ident).unwrap_or(false);
            let after_ok = !line[pos + "unsafe".len()..]
                .chars()
                .next()
                .map(is_ident)
                .unwrap_or(false);
            before_ok && after_ok
        });
        if !has_unsafe {
            continue;
        }
        let mut ok = c.comment[i].contains("SAFETY:");
        let mut k = i;
        while !ok && k > 0 && c.code[k - 1].trim().is_empty() && !c.comment[k - 1].trim().is_empty()
        {
            k -= 1;
            ok = c.comment[k].contains("SAFETY:");
        }
        if !ok && !is_allowed(c, i, "safety_comment") {
            out.push(Finding::new(
                rel,
                i,
                "safety_comment",
                "unsafe without a preceding // SAFETY: justification".to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------------------
// entry point
// ---------------------------------------------------------------------------

/// Lint one file's source. `rel` is the repo-relative path (it selects
/// the per-file rule scopes and domain tables).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let c = clean(src);
    let tests = test_ranges(&c.code);
    let depth = depth_map(&c.code);
    let mut out = Vec::new();
    rule_no_panic(rel, &c, &tests, &mut out);
    rule_lock(rel, &c, &tests, &depth, &mut out);
    rule_probe_gate(rel, &c, &tests, &mut out);
    rule_safety(rel, &c, &tests, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_or_else_is_not_a_panic_site() {
        let f = lint_source("coordinator/x.rs", "fn f(v: Option<u32>) -> u32 {\n    v.unwrap_or_else(|| 0)\n}\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn expect_err_is_not_expect() {
        let f = lint_source("coordinator/x.rs", "fn f() {\n    let _ = r().expect_err;\n}\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn debug_assert_is_not_panic_macro() {
        let f = lint_source(
            "coordinator/x.rs",
            "fn f() {\n    debug_assert!(true);\n    assert!(true);\n}\n",
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn util_files_are_out_of_no_panic_scope() {
        let f = lint_source("util/x.rs", "fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn multiline_lock_chain_resolves_receiver() {
        let src = concat!(
            "impl Service {\n",
            "    fn f(&self) -> usize {\n",
            "        let n = self\n",
            "            .state\n",
            "            .lock_unpoisoned()\n",
            "            .len();\n",
            "        n\n",
            "    }\n",
            "}\n",
        );
        let f = lint_source("coordinator/service.rs", src);
        assert!(f.is_empty(), "chain receiver must resolve to 'state': {f:?}");
    }

    #[test]
    fn unknown_receiver_is_flagged() {
        let src = "impl S {\n    fn f(&self) {\n        let g = self.mystery.lock_unpoisoned();\n        drop(g);\n    }\n}\n";
        let f = lint_source("coordinator/service.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "lock_order");
        assert!(f[0].message.contains("unregistered"), "{}", f[0].message);
    }

    #[test]
    fn double_acquire_same_domain_is_flagged() {
        let src = concat!(
            "impl Service {\n",
            "    fn f(&self) {\n",
            "        let a = self.state.lock_unpoisoned();\n",
            "        let b = self.state.lock_unpoisoned();\n",
            "        drop(b);\n",
            "        drop(a);\n",
            "    }\n",
            "}\n",
        );
        let f = lint_source("coordinator/service.rs", src);
        assert!(
            f.iter().any(|x| x.rule == "lock_order" && x.line == 4),
            "self-deadlock must be flagged: {f:?}"
        );
    }

    #[test]
    fn scoped_block_guard_does_not_leak_into_tail() {
        // the brace-scoped guard drops at the block close; the trace call
        // after it is clean
        let src = concat!(
            "impl Service {\n",
            "    fn f(&self) {\n",
            "        let d = {\n",
            "            let g = self.router.lock_unpoisoned();\n",
            "            g.decide()\n",
            "        };\n",
            "        crate::trace::instant(crate::trace::kind::ROUTE_DECIDE, None, \"t\", d);\n",
            "    }\n",
            "}\n",
        );
        let f = lint_source("coordinator/service.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }
}
