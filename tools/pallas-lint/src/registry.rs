//! rule: registry_sync — whole-tree facts, not per-line patterns.
//!
//! Three registries must stay in lockstep with their consumers:
//! * every `Metrics` counter (a `u64`/`f64` field of `struct Inner`) is in
//!   `bench/metricsjson.rs::REQUIRED_NUMERIC` and documented in
//!   docs/BENCHMARKS.md;
//! * every trace kind constant in `trace::kind` is in
//!   `trace/chrome.rs::KNOWN_KINDS` (and vice versa — no ghost entries);
//! * every typed error code string in `coordinator/reliability.rs` /
//!   `coordinator/journal.rs` appears verbatim in docs/RELIABILITY.md.
//!
//! Identification runs on *cleaned* lines (comments can mention anything),
//! but the literal values must come from the *raw* lines — the lexer blanks
//! string contents.

use crate::engine::Finding;
use crate::lexer::clean;

/// File contents the checker compares. Tests feed fixture contents; the
/// binary reads the real tree (see [`crate::lint_tree`]).
pub struct RegistryInputs<'a> {
    pub metrics: &'a str,
    pub metricsjson: &'a str,
    pub benchmarks_doc: &'a str,
    pub trace_mod: &'a str,
    pub chrome: &'a str,
    pub reliability: &'a str,
    pub journal: &'a str,
    pub reliability_doc: &'a str,
}

const F_METRICS: &str = "rust/src/coordinator/metrics.rs";
const F_TRACE: &str = "rust/src/trace/mod.rs";
const F_CHROME: &str = "rust/src/trace/chrome.rs";
const F_RELIABILITY: &str = "rust/src/coordinator/reliability.rs";
const F_JOURNAL: &str = "rust/src/coordinator/journal.rs";

/// First `"…"` literal on a raw line.
fn quoted(raw: &str) -> Option<&str> {
    let a = raw.find('"')?;
    let rest = &raw[a + 1..];
    let b = rest.find('"')?;
    Some(&rest[..b])
}

/// End line (inclusive) of the brace block opened on `start`.
fn block_end(code: &[String], start: usize) -> usize {
    let mut d = 0i32;
    let mut opened = false;
    for (j, line) in code.iter().enumerate().skip(start) {
        for ch in line.chars() {
            if ch == '{' {
                d += 1;
                opened = true;
            } else if ch == '}' {
                d -= 1;
            }
        }
        if opened && d <= 0 {
            return j;
        }
    }
    code.len().saturating_sub(1)
}

/// `u64`/`f64` fields of `struct Inner { … }` — the counter registry.
/// `Accumulator` fields are sketches, exported via their derived keys.
fn inner_counters(metrics_src: &str) -> Option<Vec<String>> {
    let c = clean(metrics_src);
    let start = c.code.iter().position(|l| l.contains("struct Inner {"))?;
    let end = block_end(&c.code, start);
    let mut out = Vec::new();
    for line in &c.code[start + 1..=end] {
        let t = line.trim().trim_end_matches(',');
        let Some((name, ty)) = t.split_once(':') else { continue };
        let name = name.trim();
        let ty = ty.trim();
        if !name.is_empty()
            && name.chars().all(|ch| ch.is_ascii_alphanumeric() || ch == '_')
            && (ty == "u64" || ty == "f64")
        {
            out.push(name.to_string());
        }
    }
    Some(out)
}

/// `pub const NAME: &str = "value";` pairs inside the given cleaned range,
/// with values pulled from the raw lines.
fn str_consts(src: &str, lo: usize, hi: usize) -> Vec<(String, String)> {
    let c = clean(src);
    let raw: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for j in lo..=hi.min(c.code.len().saturating_sub(1)) {
        let line = &c.code[j];
        if !(line.contains("pub const ") && line.contains("&str")) {
            continue;
        }
        let Some(p) = line.find("pub const ") else { continue };
        let rest = &line[p + "pub const ".len()..];
        let Some(colon) = rest.find(':') else { continue };
        let name = rest[..colon].trim().to_string();
        let Some(val) = raw.get(j).and_then(|r| quoted(r)) else { continue };
        out.push((name, val.to_string()));
    }
    out
}

/// Trace kind literals declared in `pub mod kind { … }`.
fn trace_kinds(trace_src: &str) -> Option<Vec<String>> {
    let c = clean(trace_src);
    let start = c.code.iter().position(|l| l.contains("pub mod kind {"))?;
    let end = block_end(&c.code, start);
    Some(str_consts(trace_src, start + 1, end).into_iter().map(|(_, v)| v).collect())
}

/// The `KNOWN_KINDS` array literal. Anchors on the cleaned declaration
/// line, then char-scans the raw text: skip to `=` first (the type
/// annotation `[&str; N]` has a `[` of its own), then `[`, collect
/// quoted strings until `]`.
fn known_kinds(chrome_src: &str) -> Option<Vec<String>> {
    let c = clean(chrome_src);
    let raw: Vec<&str> = chrome_src.lines().collect();
    let start = c
        .code
        .iter()
        .position(|l| l.contains("KNOWN_KINDS") && l.contains('='))?;
    let tail = raw.get(start..)?.join("\n");
    let p = tail.find("KNOWN_KINDS")?;
    let tail = &tail[p..];
    let eq = tail.find('=')?;
    let tail = &tail[eq..];
    let open = tail.find('[')?;
    let body = &tail[open..];
    let close = body.find(']')?;
    let body = &body[..close];
    let mut out = Vec::new();
    let mut rest = body;
    while let Some(a) = rest.find('"') {
        let after = &rest[a + 1..];
        let Some(b) = after.find('"') else { break };
        out.push(after[..b].to_string());
        rest = &after[b + 1..];
    }
    Some(out)
}

fn finding(file: &str, message: String) -> Finding {
    Finding::new(file, 0, "registry_sync", message)
}

pub fn check_registry(inp: &RegistryInputs) -> Vec<Finding> {
    let mut out = Vec::new();

    // -- metrics counters ↔ METRICS.json schema ↔ docs/BENCHMARKS.md -------
    match inner_counters(inp.metrics) {
        None => out.push(finding(F_METRICS, "cannot locate `struct Inner`".to_string())),
        Some(counters) => {
            for c in &counters {
                if !inp.metricsjson.contains(&format!("\"{c}\"")) {
                    out.push(finding(F_METRICS, format!(
                        "counter '{c}' missing from bench/metricsjson.rs REQUIRED_NUMERIC"
                    )));
                }
                if !inp.benchmarks_doc.contains(&format!("`{c}`")) {
                    out.push(finding(F_METRICS, format!(
                        "counter '{c}' undocumented in docs/BENCHMARKS.md"
                    )));
                }
            }
        }
    }

    // -- trace kinds ↔ chrome exporter KNOWN_KINDS --------------------------
    let kinds = trace_kinds(inp.trace_mod).unwrap_or_default();
    if kinds.is_empty() {
        out.push(finding(F_TRACE, "cannot locate `pub mod kind`".to_string()));
    }
    let known = known_kinds(inp.chrome).unwrap_or_default();
    if known.is_empty() {
        out.push(finding(F_CHROME, "cannot locate KNOWN_KINDS".to_string()));
    }
    for k in &kinds {
        if !known.contains(k) {
            out.push(finding(F_TRACE, format!(
                "trace kind '{k}' missing from trace/chrome.rs KNOWN_KINDS"
            )));
        }
    }
    for k in &known {
        if !kinds.contains(k) {
            out.push(finding(F_CHROME, format!(
                "KNOWN_KINDS entry '{k}' has no constant in trace::kind"
            )));
        }
    }

    // -- typed error codes ↔ docs/RELIABILITY.md ----------------------------
    for (file, src) in [(F_RELIABILITY, inp.reliability), (F_JOURNAL, inp.journal)] {
        let last = src.lines().count().saturating_sub(1);
        for (name, val) in str_consts(src, 0, last) {
            if name == "SCHEMA" {
                continue;
            }
            if !inp.reliability_doc.contains(&val) {
                out.push(finding(file, format!(
                    "error code {name} (\"{val}\") undocumented in docs/RELIABILITY.md"
                )));
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_kinds_skips_type_annotation_bracket() {
        let src = "pub const KNOWN_KINDS: [&str; 2] = [\n    \"a.b\", \"c.d\",\n];\n";
        assert_eq!(known_kinds(src), Some(vec!["a.b".to_string(), "c.d".to_string()]));
    }

    #[test]
    fn known_kinds_ignores_comment_mentions() {
        let src = "// KNOWN_KINDS = [\"fake\"] in prose\npub const KNOWN_KINDS: [&str; 1] = [\"x.y\"];\n";
        assert_eq!(known_kinds(src), Some(vec!["x.y".to_string()]));
    }

    #[test]
    fn inner_counters_skip_accumulators_and_comments() {
        let src = concat!(
            "struct Inner {\n",
            "    submitted: u64,\n",
            "    // ghost: u64, (commented out)\n",
            "    hedge_wasted_s: f64,\n",
            "    wait: Accumulator,\n",
            "}\n",
        );
        assert_eq!(inner_counters(src), Some(vec![
            "submitted".to_string(),
            "hedge_wasted_s".to_string(),
        ]));
    }

    #[test]
    fn str_consts_pull_values_from_raw_lines() {
        let src = "pub mod kind {\n    pub const A: &str = \"x.y\"; // note\n}\n";
        assert_eq!(trace_kinds(src), Some(vec!["x.y".to_string()]));
    }
}
