//! Structural passes shared by every rule: brace-depth map, test-range
//! detection (`#[cfg(test)]` modules and `#[test]` fns are exempt from
//! the hot-path rules), and the `lint:allow(<rule>) reason` directive.

use crate::lexer::Cleaned;

/// One reported violation. `line` is 1-based (editor-clickable).
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    pub fn new(file: &str, line0: usize, rule: &'static str, message: String) -> Finding {
        Finding { file: file.to_string(), line: line0 + 1, rule, message }
    }
}

/// `depth[i]` = brace depth entering line `i` (computed over cleaned code,
/// so braces in strings/chars/comments never skew it).
pub fn depth_map(code: &[String]) -> Vec<i32> {
    let mut before = Vec::with_capacity(code.len());
    let mut d = 0i32;
    for line in code {
        before.push(d);
        for ch in line.chars() {
            if ch == '{' {
                d += 1;
            } else if ch == '}' {
                d -= 1;
            }
        }
    }
    before
}

/// Lines covered by `#[cfg(test)]` items and `#[test]` fns: the attribute
/// line through the matching close brace of the following item.
pub fn test_ranges(code: &[String]) -> Vec<bool> {
    let n = code.len();
    let mut covered = vec![false; n];
    let mut i = 0usize;
    while i < n {
        let t = code[i].trim();
        if t.starts_with("#[cfg(test)]") || t == "#[test]" {
            let mut j = i;
            let mut depth = 0i32;
            let mut opened = false;
            while j < n {
                for ch in code[j].chars() {
                    if ch == '{' {
                        depth += 1;
                        opened = true;
                    } else if ch == '}' {
                        depth -= 1;
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                j += 1;
            }
            let end = j.min(n - 1);
            for flag in covered.iter_mut().take(end + 1).skip(i) {
                *flag = true;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    covered
}

/// Does this comment text carry `lint:allow(<rule>)`?
pub fn directive_allows(comment: &str, rule: &str) -> bool {
    const NEEDLE: &str = "lint:allow(";
    let mut rest = comment;
    while let Some(p) = rest.find(NEEDLE) {
        let after = &rest[p + NEEDLE.len()..];
        match after.find(')') {
            Some(end) => {
                if after[..end].trim() == rule {
                    return true;
                }
                rest = &after[end..];
            }
            None => return false,
        }
    }
    false
}

/// A finding at `line` is suppressed when a `lint:allow(rule)` directive
/// sits in the same line's trailing comment, or anywhere in the run of
/// comment/blank lines immediately above it (so a multi-line
/// justification can carry the directive on its first line).
pub fn is_allowed(c: &Cleaned, line: usize, rule: &str) -> bool {
    if directive_allows(&c.comment[line], rule) {
        return true;
    }
    let mut k = line;
    while k > 0 && c.code[k - 1].trim().is_empty() {
        k -= 1;
        if directive_allows(&c.comment[k], rule) {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::clean;

    #[test]
    fn directive_parsing() {
        assert!(directive_allows(" lint:allow(no_panic) startup is fallible", "no_panic"));
        assert!(!directive_allows(" lint:allow(no_panic) reason", "lock_order"));
        assert!(directive_allows(" x lint:allow(a) lint:allow(lock_order) y", "lock_order"));
        assert!(!directive_allows(" lint:allow(", "no_panic"));
    }

    #[test]
    fn allow_on_same_line_and_preceding_block() {
        let c = clean(concat!(
            "let a = x.unwrap(); // lint:allow(no_panic) same line\n",
            "// lint:allow(no_panic) block form:\n",
            "// spanning two comment lines\n",
            "let b = y.unwrap();\n",
            "let c = z.unwrap();\n",
        ));
        assert!(is_allowed(&c, 0, "no_panic"));
        assert!(is_allowed(&c, 3, "no_panic"));
        assert!(!is_allowed(&c, 4, "no_panic"), "directive must not leak past its target");
    }

    #[test]
    fn test_ranges_cover_cfg_test_mod() {
        let c = clean(concat!(
            "fn hot() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() {}\n",
            "}\n",
            "fn also_hot() {}\n",
        ));
        let t = test_ranges(&c.code);
        assert!(!t[0]);
        assert!(t[1] && t[2] && t[4] && t[5]);
        assert!(!t[6]);
    }
}
