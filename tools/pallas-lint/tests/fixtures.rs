//! Self-test over the seeded-violation fixture corpus: every rule fires
//! exactly once at the seeded line, the allowlisted and clean shapes stay
//! silent — then the real tree must lint clean, so `cargo test -p
//! pallas-lint` alone enforces the invariants.

use pallas_lint::registry::{check_registry, RegistryInputs};
use pallas_lint::rules::lint_source;
use pallas_lint::Finding;
use std::path::Path;

/// Assert exactly one finding of `rule` at 1-based `line`.
fn assert_single(findings: &[Finding], rule: &str, line: usize) {
    assert_eq!(
        findings.len(),
        1,
        "expected exactly one finding, got: {findings:?}"
    );
    assert_eq!(findings[0].rule, rule, "{findings:?}");
    assert_eq!(findings[0].line, line, "{findings:?}");
}

#[test]
fn no_panic_fires_once_allowlist_and_tests_exempt() {
    let f = lint_source("coordinator/service.rs", include_str!("fixtures/no_panic.rs"));
    assert_single(&f, "no_panic", 6);
}

#[test]
fn lock_scope_fires_once_on_guard_spanning_trace_call() {
    let f = lint_source("coordinator/service.rs", include_str!("fixtures/lock_scope.rs"));
    assert_single(&f, "lock_scope", 14);
}

#[test]
fn lock_order_fires_once_on_inverted_nesting() {
    let f = lint_source("coordinator/service.rs", include_str!("fixtures/lock_order.rs"));
    assert_single(&f, "lock_order", 16);
}

#[test]
fn probe_gate_fires_once_on_allocating_gate() {
    let f = lint_source("trace/mod.rs", include_str!("fixtures/probe_gate.rs"));
    assert_single(&f, "probe_gate", 5);
}

#[test]
fn probe_gate_fires_once_on_locking_simd_tier_gate() {
    let f = lint_source("fitter/simd/mod.rs", include_str!("fixtures/probe_gate_simd.rs"));
    assert_single(&f, "probe_gate", 7);
}

#[test]
fn safety_comment_fires_once_on_undocumented_unsafe() {
    let f = lint_source("runtime/fixture.rs", include_str!("fixtures/safety_comment.rs"));
    assert_single(&f, "safety_comment", 7);
}

#[test]
fn registry_sync_flags_all_four_seeded_drifts() {
    let f = check_registry(&RegistryInputs {
        metrics: include_str!("fixtures/registry/metrics.rs"),
        metricsjson: include_str!("fixtures/registry/metricsjson.rs"),
        benchmarks_doc: include_str!("fixtures/registry/BENCHMARKS.md"),
        trace_mod: include_str!("fixtures/registry/trace_mod.rs"),
        chrome: include_str!("fixtures/registry/chrome.rs"),
        reliability: include_str!("fixtures/registry/reliability.rs"),
        journal: "",
        reliability_doc: include_str!("fixtures/registry/RELIABILITY.md"),
    });
    assert_eq!(f.len(), 4, "{f:?}");
    let has = |needle: &str| f.iter().any(|x| x.message.contains(needle));
    assert!(has("'bogus_counter' missing from bench/metricsjson.rs"), "{f:?}");
    assert!(has("'bogus_counter' undocumented in docs/BENCHMARKS.md"), "{f:?}");
    assert!(has("'ghost.kind' missing from trace/chrome.rs KNOWN_KINDS"), "{f:?}");
    assert!(has("LOST_IN_SPACE (\"lost in space\") undocumented"), "{f:?}");
}

#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let findings = pallas_lint::lint_tree(&root).expect("repo tree readable");
    assert!(
        findings.is_empty(),
        "the tree must lint clean; findings:\n{}",
        findings
            .iter()
            .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
