//! pallas-lint fixture: `lock_order`. Linted under the
//! `coordinator/service.rs` domain table; the seeded nesting acquires
//! `router` while holding `state`, which the declared partial order
//! forbids. The `router -> metrics` nesting is part of the declared
//! order and must stay clean.

impl Service {
    fn ordered_ok(&self) {
        let mut guard = self.router.lock_unpoisoned();
        self.metrics.task_routed(true, false);
        drop(guard);
    }

    fn inverted(&self) {
        let mut g = self.state.lock_unpoisoned();
        let r = self.router.lock_unpoisoned();
        drop(r);
        drop(g);
    }

    fn inverted_allowed(&self) {
        let mut g = self.state.lock_unpoisoned();
        // lint:allow(lock_order) fixture: documents the suppression path
        let r = self.router.lock_unpoisoned();
        drop(r);
        drop(g);
    }
}
