//! pallas-lint fixture: `safety_comment`. One seeded `unsafe` without a
//! `// SAFETY:` justification; the documented and allowlisted impls must
//! stay clean.

struct Raw(*mut u8);

unsafe impl Send for Raw {}

struct Documented(*mut u8);

// SAFETY: fixture — the pointer is owned by one thread and never shared.
unsafe impl Send for Documented {}

struct Suppressed(*mut u8);

// lint:allow(safety_comment) fixture: documents the suppression path
unsafe impl Send for Suppressed {}
