// pallas-lint fixture: the exporter registry knows `task.submit` only.

pub const KNOWN_KINDS: [&str; 1] = [
    "task.submit",
];
