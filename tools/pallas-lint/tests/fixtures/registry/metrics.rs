// pallas-lint fixture: registry_sync — `bogus_counter` exists on the hub
// but is neither exported by metricsjson.rs nor documented.

struct Inner {
    submitted: u64,
    bogus_counter: u64,
    wait: Accumulator,
}
