// pallas-lint fixture: `ghost.kind` is emitted by the hub but absent
// from the exporter's KNOWN_KINDS registry.

pub mod kind {
    pub const TASK_SUBMIT: &str = "task.submit";
    pub const GHOST: &str = "ghost.kind";
}
