// pallas-lint fixture: the exported schema knows `submitted` only —
// `bogus_counter` is missing, which registry_sync must flag.

const REQUIRED_NUMERIC: [&str; 1] = ["submitted"];
