// pallas-lint fixture: `LOST_IN_SPACE` is a typed error code whose
// literal string never made it into the reliability docs.

pub const SCHEMA: &str = "fixture/schema/v1";
pub const DEADLINE_EXCEEDED: &str = "deadline exceeded";
pub const LOST_IN_SPACE: &str = "lost in space";
