//! pallas-lint fixture: `probe_gate`. Linted as `trace/mod.rs`; the gate
//! allocates on the disabled fast path — exactly one seeded violation.

pub fn enabled() -> bool {
    let label = format!("gate");
    ENABLED.load(Ordering::Relaxed) && !label.is_empty()
}
