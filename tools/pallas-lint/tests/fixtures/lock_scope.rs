//! pallas-lint fixture: `lock_scope`. Linted under the
//! `coordinator/service.rs` domain table; the seeded guard spans a call
//! into the trace hub, the other two shapes must stay clean.

impl Service {
    fn scope_ok(&self) {
        let g = self.state.lock_unpoisoned();
        drop(g);
        crate::trace::instant(crate::trace::kind::TASK_SUBMIT, None, "t", String::new());
    }

    fn scope_bad(&self) {
        let g = self.state.lock_unpoisoned();
        crate::trace::instant(crate::trace::kind::TASK_SUBMIT, None, "t", String::new());
        drop(g);
    }

    fn scope_allowed(&self) {
        let g = self.state.lock_unpoisoned();
        // lint:allow(lock_scope) fixture: documents the suppression path
        crate::trace::instant(crate::trace::kind::TASK_SUBMIT, None, "t", String::new());
        drop(g);
    }
}
