//! pallas-lint fixture: `probe_gate` on the SIMD tier dispatch gate.
//! Linted as `fitter/simd/mod.rs`; the gate performs its relaxed load but
//! then takes a lock on the fast path — exactly one seeded violation.

pub fn active() -> Tier {
    let t = TIER.load(Ordering::Relaxed);
    let _double_check = *TIER_SLOW.lock().unwrap();
    Tier::from_u8(t)
}
