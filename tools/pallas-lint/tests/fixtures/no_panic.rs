//! pallas-lint fixture: `no_panic`. Linted as a hot-path file
//! (`coordinator/…`); exactly one seeded violation must fire, the
//! allowlisted site and the test module must not.

pub fn hot(v: Option<u32>) -> u32 {
    v.unwrap() // seeded violation: panic site on the hot path
}

pub fn justified(v: Option<u32>) -> u32 {
    // lint:allow(no_panic) fixture: documents the suppression path
    v.expect("fixture invariant")
}

pub fn graceful(v: Option<u32>) -> u32 {
    // unwrap_or / unwrap_or_else must NOT match the unwrap() pattern
    v.unwrap_or_else(|| 0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        assert_eq!(super::hot(Some(1)), 1);
        Option::<u32>::Some(2).unwrap();
        Option::<u32>::Some(3).expect("tests may panic freely");
        panic!("and even this is fine in a test");
    }
}
