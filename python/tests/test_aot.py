"""AOT artifact emission: manifest contract + HLO text sanity."""

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import aot
from compile.shapes import INPUT_ORDER, OUTPUT_ORDER, SHAPE_CLASSES, input_shapes


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    classes = {"quickstart": SHAPE_CLASSES["quickstart"]}
    manifest = aot.build_all(out, classes=classes, verbose=False)
    return out, manifest


def test_manifest_contract(built):
    out, manifest = built
    assert manifest["format"] == "hlo-text"
    assert manifest["dtype"] == "f64"
    assert manifest["input_order"] == INPUT_ORDER
    assert manifest["output_order"] == OUTPUT_ORDER
    assert set(manifest["entries"]) == {"hypotest_quickstart", "mle_quickstart"}


def test_manifest_shapes_match_shape_class(built):
    _, manifest = built
    cfg = SHAPE_CLASSES["quickstart"]
    shapes = input_shapes(cfg)
    entry = manifest["entries"]["hypotest_quickstart"]
    assert entry["shape_class"]["n_params"] == cfg.n_params
    for spec in entry["inputs"]:
        assert tuple(spec["shape"]) == shapes[spec["name"]]
        assert spec["dtype"] == "f64"
    assert [s["name"] for s in entry["inputs"]] == INPUT_ORDER


def test_hlo_text_files_exist_and_parse_shape(built):
    out, manifest = built
    for entry in manifest["entries"].values():
        path = os.path.join(out, entry["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # all inputs appear as f64 parameters
        assert text.count("parameter(") >= len(INPUT_ORDER)
        # interchange must not contain opcodes newer than xla_extension 0.5.1
        for banned in (" erf(", " erf-inv(", "custom-call"):
            assert banned not in text, f"banned opcode {banned!r} in {path}"


def test_manifest_json_round_trips(built):
    out, manifest = built
    on_disk = json.load(open(os.path.join(out, "manifest.json")))
    assert on_disk["entries"].keys() == manifest["entries"].keys()
    assert on_disk["input_order"] == manifest["input_order"]
