"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core numeric signal for the whole stack: the AOT artifact embeds
the Pallas graph, Rust executes it blindly, so kernel==oracle here is what
makes the Rust-side answers trustworthy.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref as kref
from compile.kernels.expected import expected_and_jacobian_pallas
from compile.kernels.nll import poisson_nll_pallas
from compile.shapes import SHAPE_CLASSES
from compile.synth import make_tensors, random_theta

CLASSES = list(SHAPE_CLASSES)


@pytest.mark.parametrize("name", CLASSES)
def test_expected_kernel_matches_ref(name):
    cfg = SHAPE_CLASSES[name]
    t = make_tensors(cfg, seed=11)
    th = random_theta(cfg, t, seed=12)
    nu_r, j_r = kref.expected_and_jacobian_ref(th, t, cfg)
    nu_p, j_p = expected_and_jacobian_pallas(th, t, cfg)
    np.testing.assert_allclose(nu_p, nu_r, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(j_p, j_r, rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("name", CLASSES)
def test_nll_kernel_matches_ref(name):
    cfg = SHAPE_CLASSES[name]
    t = make_tensors(cfg, seed=21)
    th = random_theta(cfg, t, seed=22)
    nu, _ = kref.expected_and_jacobian_ref(th, t, cfg)
    r = kref.poisson_nll_ref(nu, t["data"], t["bin_mask"])
    p = poisson_nll_pallas(jnp.asarray(nu), t["data"], t["bin_mask"], cfg)
    np.testing.assert_allclose(float(p), float(r), rtol=1e-13)


def test_jacobian_matches_jacfwd():
    """Analytic kernel Jacobian == forward-mode autodiff of the oracle."""
    cfg = SHAPE_CLASSES["quickstart"]
    t = make_tensors(cfg, seed=5, active_bins=12, active_alpha=5)
    th = random_theta(cfg, t, seed=6)
    _, j_ana = expected_and_jacobian_pallas(th, t, cfg)
    jf = jax.jacfwd(
        lambda x: kref.expected_and_jacobian_ref(x, t, cfg)[0])(jnp.asarray(th))
    np.testing.assert_allclose(np.asarray(jf).T, j_ana, rtol=1e-9, atol=1e-9)


def test_jacobian_matches_jacfwd_at_negative_alpha():
    """The code0/code1 sign branches must differentiate correctly on both sides."""
    cfg = SHAPE_CLASSES["quickstart"]
    t = make_tensors(cfg, seed=5, active_bins=12, active_alpha=5)
    th = random_theta(cfg, t, seed=6)
    f = cfg.n_free
    th[f:f + cfg.n_alpha] = -np.abs(th[f:f + cfg.n_alpha]) - 0.05
    _, j_ana = expected_and_jacobian_pallas(th, t, cfg)
    jf = jax.jacfwd(
        lambda x: kref.expected_and_jacobian_ref(x, t, cfg)[0])(jnp.asarray(th))
    np.testing.assert_allclose(np.asarray(jf).T, j_ana, rtol=1e-9, atol=1e-9)


def test_masked_parameters_have_zero_jacobian():
    cfg = SHAPE_CLASSES["quickstart"]
    t = make_tensors(cfg, seed=9, active_bins=10, active_alpha=3)
    th = random_theta(cfg, t, seed=10)
    _, jac = expected_and_jacobian_pallas(th, t, cfg)
    f, a = cfg.n_free, cfg.n_alpha
    # inactive alphas
    assert np.all(jac[f + 3:f + a, :] == 0.0)
    # gammas of padded bins (ctype == 0)
    pad = np.where(t["ctype"] == 0.0)[0]
    assert np.all(jac[f + a + pad, :] == 0.0)


def test_pinned_parameters_do_not_change_expectation():
    cfg = SHAPE_CLASSES["quickstart"]
    t = make_tensors(cfg, seed=13, active_bins=10, active_alpha=3)
    th1 = random_theta(cfg, t, seed=14)
    th2 = th1.copy()
    f, a = cfg.n_free, cfg.n_alpha
    th2[f + 4] = 3.0          # masked alpha
    th2[f + a + 11] = 0.123   # padded-bin gamma
    nu1, _ = expected_and_jacobian_pallas(th1, t, cfg)
    nu2, _ = expected_and_jacobian_pallas(th2, t, cfg)
    np.testing.assert_array_equal(np.asarray(nu1), np.asarray(nu2))


def test_gamma_jacobian_is_bin_diagonal():
    cfg = SHAPE_CLASSES["quickstart"]
    t = make_tensors(cfg, seed=15)
    th = random_theta(cfg, t, seed=16)
    _, jac = expected_and_jacobian_pallas(th, t, cfg)
    f, a, b = cfg.n_free, cfg.n_alpha, cfg.n_bins
    g = np.asarray(jac[f + a:, :])
    off = g - np.diag(np.diag(g))
    assert np.abs(off).max() == 0.0


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    tseed=st.integers(0, 10_000),
    nb=st.integers(2, 16),
    na=st.integers(0, 6),
    mu=st.floats(0.0, 5.0),
)
def test_kernel_matches_ref_hypothesis(seed, tseed, nb, na, mu):
    """Property sweep over workspace shapes, activity masks and theta points."""
    cfg = SHAPE_CLASSES["quickstart"]
    t = make_tensors(cfg, seed=seed, active_bins=nb, active_alpha=na, data_mu=mu)
    th = random_theta(cfg, t, seed=tseed)
    nu_r, j_r = kref.expected_and_jacobian_ref(th, t, cfg)
    nu_p, j_p = expected_and_jacobian_pallas(th, t, cfg)
    np.testing.assert_allclose(nu_p, nu_r, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(j_p, j_r, rtol=1e-12, atol=1e-12)
    r = kref.poisson_nll_ref(nu_r, t["data"], t["bin_mask"])
    p = poisson_nll_pallas(jnp.asarray(nu_r), t["data"], t["bin_mask"], cfg)
    np.testing.assert_allclose(float(p), float(r), rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(scale=st.floats(0.1, 1e3), seed=st.integers(0, 1000))
def test_kernel_scale_invariance(scale, seed):
    """nu is linear in the nominal rates when interpolations are multiplicative
    around them (histo deltas scale too).

    Scale is bounded away from zero: below ~1e-2 the additive interpolation
    can cross the EPS_RATE clip floor, where linearity intentionally breaks
    (rates are floored to keep ln(nu) finite) — found by hypothesis.
    """
    cfg = SHAPE_CLASSES["quickstart"]
    t = make_tensors(cfg, seed=seed)
    th = random_theta(cfg, t, seed=seed + 1)
    nu1, _ = expected_and_jacobian_pallas(th, t, cfg)
    t2 = dict(t)
    for k in ("nominal", "histo_up", "histo_dn"):
        t2[k] = t[k] * scale
    nu2, _ = expected_and_jacobian_pallas(th, t2, cfg)
    np.testing.assert_allclose(np.asarray(nu2), np.asarray(nu1) * scale,
                               rtol=1e-9)


@pytest.mark.parametrize("name", CLASSES)
def test_forward_only_kernel_matches_full(name):
    """The nu-only kernel (NLL path, Perf L2-1) must equal the full kernel."""
    from compile.kernels.expected import expected_pallas

    cfg = SHAPE_CLASSES[name]
    t = make_tensors(cfg, seed=31)
    th = random_theta(cfg, t, seed=32)
    nu_full, _ = expected_and_jacobian_pallas(th, t, cfg)
    nu_only = expected_pallas(th, t, cfg)
    np.testing.assert_allclose(np.asarray(nu_only), np.asarray(nu_full),
                               rtol=1e-13, atol=0)
