"""L2 correctness: fit convergence, statistical behavior, asymptotic formulas."""

import math

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.shapes import INPUT_ORDER, SHAPE_CLASSES
from compile.synth import make_tensors, random_theta

CFG = SHAPE_CLASSES["quickstart"]


def tensors(seed=3, data_mu=0.0, signal_scale=1.0):
    return make_tensors(CFG, seed=seed, active_bins=12, active_alpha=5,
                        data_mu=data_mu, signal_scale=signal_scale)


def centers(t):
    return (jnp.zeros((CFG.n_alpha,)), jnp.ones((CFG.n_bins,)))


def hypotest(t, mu_test=1.0, use_pallas=False):
    args = [jnp.asarray(t[k]) for k in INPUT_ORDER]
    fn = jax.jit(lambda *a: model.hypotest_graph(
        *a, cfg=CFG, mu_test=mu_test, use_pallas=use_pallas))
    return [np.asarray(o) for o in fn(*args)]


# ---------------------------------------------------------------------------
# numerics building blocks
# ---------------------------------------------------------------------------

def test_erf_approx_accuracy():
    xs = np.linspace(-5, 5, 201)
    ours = np.asarray(model.erf_approx(jnp.asarray(xs)))
    exact = np.array([math.erf(x) for x in xs])
    assert np.abs(ours - exact).max() < 1.6e-7


def test_norm_cdf_tails_and_center():
    assert abs(float(model.norm_cdf(jnp.asarray(0.0))) - 0.5) < 1e-7
    assert float(model.norm_cdf(jnp.asarray(5.0))) > 0.999999
    assert float(model.norm_cdf(jnp.asarray(-5.0))) < 1e-6


def test_cg_solve_matches_dense_solve():
    rng = np.random.default_rng(0)
    for n in (4, 16, 40):
        a = rng.normal(size=(n, n))
        h = a @ a.T + n * np.eye(n)
        g = rng.normal(size=n)
        x = np.asarray(model.cg_solve(jnp.asarray(h), jnp.asarray(g), n + 5))
        np.testing.assert_allclose(h @ x, g, rtol=1e-8, atol=1e-8)


def test_grad_matches_autodiff():
    """Analytic gradient (kernel Jacobian + constraint terms) == jax.grad."""
    t = tensors(seed=7)
    c = centers(t)
    th = jnp.asarray(random_theta(CFG, t, seed=8))
    fixed = model.base_fixed_mask(t, CFG)
    g_ana, _ = model.grad_and_fisher(th, t, CFG, c, fixed, use_pallas=False)
    g_ad = jax.grad(
        lambda x: model.full_nll(x, t, CFG, c, use_pallas=False))(th)
    live = np.asarray(1.0 - fixed)
    np.testing.assert_allclose(np.asarray(g_ana), np.asarray(g_ad) * live,
                               rtol=1e-8, atol=1e-8)


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mu_true", [0.0, 1.0, 2.5])
def test_fit_recovers_injected_mu(mu_true):
    t = tensors(seed=4, data_mu=mu_true, signal_scale=6.0)
    th, nll, diag = model.fit(t, CFG, centers(t), model.base_fixed_mask(t, CFG),
                              model.init_theta(t, CFG), use_pallas=False)
    assert abs(float(th[0]) - mu_true) < 0.25
    # projected-gradient norm at the optimum: the early-exit policy stops on
    # NLL stagnation, so allow a small residual (nll error ~ g^2/2h < 1e-6)
    assert float(diag[1]) < 0.05


def test_fit_decreases_nll():
    t = tensors(seed=5)
    c = centers(t)
    th0 = model.init_theta(t, CFG, mu_init=3.0)
    nll0 = float(model.full_nll(th0, t, CFG, c, use_pallas=False))
    th, nll, _ = model.fit(t, CFG, c, model.base_fixed_mask(t, CFG), th0,
                           use_pallas=False)
    assert float(nll) < nll0


def test_fixed_mu_fit_pins_poi():
    t = tensors(seed=6)
    th, _, _ = model.fit_mu_fixed(t, CFG, centers(t), 1.7, use_pallas=False)
    assert float(th[0]) == pytest.approx(1.7)


def test_fit_respects_bounds():
    # strong downward fluctuation would pull mu negative; bound keeps it >= 0
    t = tensors(seed=8, data_mu=0.0, signal_scale=10.0)
    t["data"] = np.maximum(t["data"] - 2.0 * t["nominal"][0], 0.0) * t["bin_mask"]
    th, _, _ = model.fit(t, CFG, centers(t), model.base_fixed_mask(t, CFG),
                         model.init_theta(t, CFG), use_pallas=False)
    assert float(th[0]) >= 0.0
    assert float(th[0]) <= model.FREE_LO * 10  # pushed to the boundary


def test_fixed_params_do_not_move():
    t = tensors(seed=9)
    th, _, _ = model.fit_mu_fixed(t, CFG, centers(t), 1.0, use_pallas=False)
    f, a = CFG.n_free, CFG.n_alpha
    # masked alphas stay at init 0; padded-bin gammas stay at 1
    assert np.all(np.asarray(th[f + 5:f + a]) == 0.0)
    pad = np.where(t["ctype"] == 0.0)[0]
    assert np.all(np.asarray(th)[f + a + pad] == 1.0)


# ---------------------------------------------------------------------------
# hypothesis test statistics
# ---------------------------------------------------------------------------

def test_hypotest_bkg_only_matches_expected_band():
    out = hypotest(tensors(seed=3, data_mu=0.0))
    cls_obs, cls_exp = out[0], out[1]
    assert 0.0 <= cls_obs <= 1.0
    # observed on background-like data should sit inside the +-2 sigma band
    assert cls_exp[0] <= cls_obs <= cls_exp[4]


def test_hypotest_expected_band_is_monotonic():
    out = hypotest(tensors(seed=3))
    cls_exp = out[1]
    assert np.all(np.diff(cls_exp) > 0)


def test_hypotest_signal_injection_raises_cls():
    bkg = hypotest(tensors(seed=3, data_mu=0.0, signal_scale=4.0))
    sig = hypotest(tensors(seed=3, data_mu=1.0, signal_scale=4.0))
    assert sig[0] > bkg[0]
    assert sig[4] > 0.5  # mu_hat near 1


def test_hypotest_more_signal_more_power():
    weak = hypotest(tensors(seed=3, signal_scale=1.0))
    strong = hypotest(tensors(seed=3, signal_scale=5.0))
    # median expected CLs must drop with signal cross-section
    assert strong[1][2] < weak[1][2]
    assert strong[3] > weak[3]  # qmu_A grows


def test_hypotest_qmu_nonnegative_and_mu_hat_bounded():
    for seed in (1, 2, 3, 4):
        out = hypotest(tensors(seed=seed, data_mu=float(seed % 3)))
        assert out[2] >= 0.0 and out[3] >= 0.0
        assert 0.0 <= out[4] <= CFG.mu_max


def test_hypotest_pallas_equals_jnp_graph():
    t = tensors(seed=3)
    a = hypotest(t, use_pallas=False)
    b = hypotest(t, use_pallas=True)
    np.testing.assert_allclose(a[0], b[0], rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(a[1], b[1], rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(a[4], b[4], rtol=1e-9, atol=1e-12)


def test_asimov_free_nll_is_minimum():
    """The background-only fit point must minimize the Asimov NLL (the
    justification for skipping the 5th fit in hypotest_graph)."""
    t = tensors(seed=3)
    c = centers(t)
    th_bkg, _, _ = model.fit_mu_fixed(t, CFG, c, model.FREE_LO, use_pallas=False)
    nu_bkg, _ = model.expected_and_jacobian(th_bkg, t, CFG, use_pallas=False)
    from compile.kernels import ref as kref
    _, a_bkg, g_bkg = kref.effective_params(th_bkg, t, CFG)
    ta = dict(t, data=np.asarray(nu_bkg))
    ca = (a_bkg, g_bkg)
    nll0 = float(model.full_nll(th_bkg, ta, CFG, ca, use_pallas=False))
    rng = np.random.default_rng(0)
    for _ in range(5):
        pert = np.asarray(th_bkg) + rng.normal(0, 0.05, size=CFG.n_params)
        pert[0] = np.asarray(th_bkg)[0]
        pert = np.clip(pert, 1e-6, None)
        nll_p = float(model.full_nll(jnp.asarray(pert), ta, CFG, ca,
                                     use_pallas=False))
        assert nll_p >= nll0 - 1e-9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), mu=st.floats(0.0, 2.0))
def test_hypotest_outputs_sane_hypothesis(seed, mu):
    out = hypotest(tensors(seed=seed, data_mu=mu))
    cls_obs, cls_exp, qmu, qmu_a, mu_hat = out[0], out[1], out[2], out[3], out[4]
    assert 0.0 <= cls_obs <= 1.0 + 1e-12
    assert np.all((cls_exp >= 0.0) & (cls_exp <= 1.0 + 1e-12))
    assert qmu >= 0.0 and qmu_a >= 0.0
    assert 0.0 <= mu_hat <= CFG.mu_max
