"""Pallas kernel: dense HistFactory expected rates + analytic Jacobian.

This is the compute hot-spot of the fit: it is evaluated once per Fisher-
scoring iteration per fit (4 fits per hypotest), and its outputs feed the
gradient (J @ r) and the expected-information matrix (J W J^T) assembled as
MXU-friendly matmuls in the L2 graph.

TPU schedule (expressed via BlockSpec; see DESIGN.md section 5):

* the grid runs over **bin blocks** (``cfg.bin_block`` bins per step) — bins
  are the vectorizable lane axis;
* per-block HBM->VMEM traffic is the bin-sliced tensors (``nominal``,
  ``histo_up/dn``, ``gamma_mask``, ``ctype``); the parameter-sized tensors
  (``theta``, ``norm_lnup/dn``, ``free_map``, masks) are broadcast to every
  block and stay VMEM-resident;
* outputs are the bin-sliced ``nu[B]`` and ``jac[P, B]``.

Computing the Jacobian **analytically inside the kernel** (instead of
autodiffing the model) is the key adaptation that lets the whole optimizer
live in one AOT-compiled XLA program with no Python on the request path.

Kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpret path is both the correctness oracle
target and what is shipped in the HLO artifact (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS_FREE, EPS_RATE


def _kernel(theta_ref, nominal_ref, histo_up_ref, histo_dn_ref,
            norm_lnup_ref, norm_lndn_ref, free_map_ref, free_mask_ref,
            alpha_mask_ref, gamma_mask_ref, ctype_ref,
            nu_ref, jac_ref, *, n_free, n_alpha):
    """One grid step: expected rates + Jacobian for a block of bins."""
    theta = theta_ref[...]
    f, a = n_free, n_alpha

    phi = jnp.where(free_mask_ref[...] > 0, theta[:f], 1.0)
    alpha = theta[f:f + a] * alpha_mask_ref[...]
    ctype = ctype_ref[...]
    bb = ctype.shape[0]
    gamma_blk = jax.lax.dynamic_slice(theta, (f + a + pl.program_id(0) * bb,), (bb,))
    gamma = jnp.where(ctype > 0, gamma_blk, 1.0)

    pos = alpha >= 0.0

    # --- bin-block tensors ---------------------------------------------
    nominal = nominal_ref[...]            # [S, bb]
    dside = jnp.where(pos[None, :, None], histo_up_ref[...], histo_dn_ref[...])
    delta = jnp.einsum("a,sab->sb", alpha, dside)
    raw = nominal + delta
    base = jnp.maximum(raw, EPS_RATE)
    unclipped = (raw > EPS_RATE).astype(base.dtype)

    # --- parameter-resident (broadcast) tensors ------------------------
    lnfac = jnp.where(pos[None, :], alpha[None, :] * norm_lnup_ref[...],
                      -alpha[None, :] * norm_lndn_ref[...])
    dlnfac = jnp.where(pos[None, :], norm_lnup_ref[...], -norm_lndn_ref[...])
    phis = jnp.maximum(phi, EPS_FREE)
    free_map = free_map_ref[...]
    lnmult = lnfac.sum(axis=1) + free_map @ jnp.log(phis)
    mult = jnp.exp(lnmult)                # [S]

    gmask = gamma_mask_ref[...]           # [S, bb]
    gam = 1.0 + gmask * (gamma[None, :] - 1.0)
    nu_sb = base * mult[:, None] * gam
    nu_ref[...] = nu_sb.sum(axis=0)

    # --- Jacobian block [P, bb] ----------------------------------------
    j_free = (jnp.einsum("sb,sf->fb", nu_sb, free_map) / phis[:, None])
    j_free = j_free * free_mask_ref[...][:, None]

    add_term = jnp.einsum("sab,sb->ab", dside, mult[:, None] * gam * unclipped)
    norm_term = jnp.einsum("sb,sa->ab", nu_sb, dlnfac)
    j_alpha = (add_term + norm_term) * alpha_mask_ref[...][:, None]

    jac_ref[pl.dslice(0, f), :] = j_free
    jac_ref[pl.dslice(f, a), :] = j_alpha

    # gamma rows: globally diagonal over bins. Zero the full gamma row-block
    # then scatter the in-block diagonal.
    j_gamma_diag = (nu_sb * gmask / gam).sum(axis=0) * (ctype > 0).astype(base.dtype)
    blk = pl.program_id(0)
    # rows [f+a .. f+a+B) : only rows belonging to this block's bins are nonzero
    n_bins_total = jac_ref.shape[0] - f - a
    rows = jax.lax.broadcasted_iota(jnp.int32, (n_bins_total, bb), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (n_bins_total, bb), 1)
    diag = jnp.where(rows == blk * bb + cols, j_gamma_diag[None, :], 0.0)
    jac_ref[pl.dslice(f + a, n_bins_total), :] = diag


def _kernel_nu_only(theta_ref, nominal_ref, histo_up_ref, histo_dn_ref,
                    norm_lnup_ref, norm_lndn_ref, free_map_ref, free_mask_ref,
                    alpha_mask_ref, gamma_mask_ref, ctype_ref,
                    nu_ref, *, n_free, n_alpha):
    """Forward-only variant: expected rates without the Jacobian.

    Used on the NLL-evaluation path of the optimizer (accept/reject tests),
    which needs nu but not J — skipping the Jacobian there roughly halves
    the per-iteration kernel cost (EXPERIMENTS.md §Perf, L2 iteration 1).
    """
    theta = theta_ref[...]
    f, a = n_free, n_alpha

    phi = jnp.where(free_mask_ref[...] > 0, theta[:f], 1.0)
    alpha = theta[f:f + a] * alpha_mask_ref[...]
    ctype = ctype_ref[...]
    bb = ctype.shape[0]
    gamma_blk = jax.lax.dynamic_slice(theta, (f + a + pl.program_id(0) * bb,), (bb,))
    gamma = jnp.where(ctype > 0, gamma_blk, 1.0)

    pos = alpha >= 0.0
    dside = jnp.where(pos[None, :, None], histo_up_ref[...], histo_dn_ref[...])
    delta = jnp.einsum("a,sab->sb", alpha, dside)
    base = jnp.maximum(nominal_ref[...] + delta, EPS_RATE)

    lnfac = jnp.where(pos[None, :], alpha[None, :] * norm_lnup_ref[...],
                      -alpha[None, :] * norm_lndn_ref[...])
    phis = jnp.maximum(phi, EPS_FREE)
    lnmult = lnfac.sum(axis=1) + free_map_ref[...] @ jnp.log(phis)
    mult = jnp.exp(lnmult)

    gam = 1.0 + gamma_mask_ref[...] * (gamma[None, :] - 1.0)
    nu_ref[...] = (base * mult[:, None] * gam).sum(axis=0)


def expected_pallas(theta, t, cfg):
    """Pallas forward-only expected rates nu_b[B] (no Jacobian)."""
    s, a, b, f = cfg.n_samples, cfg.n_alpha, cfg.n_bins, cfg.n_free
    bb = cfg.bin_block
    p = cfg.n_params
    grid = (b // bb,)

    kernel = functools.partial(_kernel_nu_only, n_free=f, n_alpha=a)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((s, bb), lambda i: (0, i)),
            pl.BlockSpec((s, a, bb), lambda i: (0, 0, i)),
            pl.BlockSpec((s, a, bb), lambda i: (0, 0, i)),
            pl.BlockSpec((s, a), lambda i: (0, 0)),
            pl.BlockSpec((s, a), lambda i: (0, 0)),
            pl.BlockSpec((s, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((a,), lambda i: (0,)),
            pl.BlockSpec((s, bb), lambda i: (0, i)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), theta.dtype),
        interpret=True,
    )(theta, t["nominal"], t["histo_up"], t["histo_dn"], t["norm_lnup"],
      t["norm_lndn"], t["free_map"], t["free_mask"], t["alpha_mask"],
      t["gamma_mask"], t["ctype"])


def expected_and_jacobian_pallas(theta, t, cfg):
    """Pallas implementation of ``ref.expected_and_jacobian_ref``.

    Returns ``(nu_b[B], jac[P, B])``.
    """
    s, a, b, f = cfg.n_samples, cfg.n_alpha, cfg.n_bins, cfg.n_free
    bb = cfg.bin_block
    p = cfg.n_params
    grid = (b // bb,)

    kernel = functools.partial(_kernel, n_free=f, n_alpha=a)
    nu, jac = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),            # theta (broadcast)
            pl.BlockSpec((s, bb), lambda i: (0, i)),       # nominal
            pl.BlockSpec((s, a, bb), lambda i: (0, 0, i)),  # histo_up
            pl.BlockSpec((s, a, bb), lambda i: (0, 0, i)),  # histo_dn
            pl.BlockSpec((s, a), lambda i: (0, 0)),        # norm_lnup
            pl.BlockSpec((s, a), lambda i: (0, 0)),        # norm_lndn
            pl.BlockSpec((s, f), lambda i: (0, 0)),        # free_map
            pl.BlockSpec((f,), lambda i: (0,)),            # free_mask
            pl.BlockSpec((a,), lambda i: (0,)),            # alpha_mask
            pl.BlockSpec((s, bb), lambda i: (0, i)),       # gamma_mask
            pl.BlockSpec((bb,), lambda i: (i,)),           # ctype
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),           # nu
            pl.BlockSpec((p, bb), lambda i: (0, i)),       # jac
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), theta.dtype),
            jax.ShapeDtypeStruct((p, b), theta.dtype),
        ],
        interpret=True,
    )(theta, t["nominal"], t["histo_up"], t["histo_dn"], t["norm_lnup"],
      t["norm_lndn"], t["free_map"], t["free_mask"], t["alpha_mask"],
      t["gamma_mask"], t["ctype"])
    return nu, jac
