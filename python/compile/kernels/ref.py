"""Pure-jnp reference oracle for the Pallas kernels.

Implements the dense HistFactory expected-rate computation, its analytic
parameter Jacobian, and the main Poisson NLL reduction with plain ``jnp``
operations. The Pallas kernels in ``expected.py`` / ``nll.py`` must agree with
these to ~1e-12 (checked by ``python/tests/test_kernel.py``); the Jacobian is
additionally cross-checked against ``jax.jacfwd`` of :func:`expected_ref`.

Model (see ``shapes.py`` for the tensor layout)::

    nu_sb(theta) = max(nominal_sb + sum_a delta_code0(alpha_a), eps)
                   * exp( sum_a lnfac_code1(alpha_a)_sa + sum_f M_sf ln phi_f )
                   * (1 + gamma_mask_sb * (gamma_b - 1))

with code0 (piecewise-linear) histosys interpolation and code1 (exponential)
normsys interpolation — pyhf's defaults.
"""

import jax.numpy as jnp

#: Rate floor: protects ln(nu) and marks where the additive interpolation has
#: been clipped (Jacobian contribution of clipped bins is zero).
EPS_RATE = 1e-9
#: Floor for free parameters entering logarithms / divisions.
EPS_FREE = 1e-10


def split_theta(theta, cfg):
    """Split the flat parameter vector into (phi[F], alpha[A], gamma[B])."""
    f, a = cfg.n_free, cfg.n_alpha
    return theta[:f], theta[f:f + a], theta[f + a:]


def effective_params(theta, t, cfg):
    """Apply masks: pinned free -> 1, pinned alpha -> 0, unconstrained gamma -> 1."""
    phi, alpha, gamma = split_theta(theta, cfg)
    phi = jnp.where(t["free_mask"] > 0, phi, 1.0)
    alpha = alpha * t["alpha_mask"]
    gamma = jnp.where(t["ctype"] > 0, gamma, 1.0)
    return phi, alpha, gamma


def expected_ref(theta, t, cfg):
    """Expected per-sample rates nu_sb -> [S, B]."""
    phi, alpha, gamma = effective_params(theta, t, cfg)

    # histosys, code0: delta_sb = sum_a alpha_a * (up if alpha_a >= 0 else dn)
    pos = alpha >= 0.0
    dside = jnp.where(pos[None, :, None], t["histo_up"], t["histo_dn"])
    delta = jnp.einsum("a,sab->sb", alpha, dside)
    base = jnp.maximum(t["nominal"] + delta, EPS_RATE)

    # normsys, code1: lnfac_sa = alpha*lnk+ (alpha >= 0) else -alpha*lnk-
    lnfac = jnp.where(pos[None, :], alpha[None, :] * t["norm_lnup"],
                      -alpha[None, :] * t["norm_lndn"])
    lnphi = jnp.log(jnp.maximum(phi, EPS_FREE))
    lnmult = lnfac.sum(axis=1) + t["free_map"] @ lnphi  # [S]
    mult = jnp.exp(lnmult)

    gam = 1.0 + t["gamma_mask"] * (gamma[None, :] - 1.0)  # [S, B]
    return base * mult[:, None] * gam


def expected_and_jacobian_ref(theta, t, cfg):
    """Return (nu_b[B], J[P, B]) with J_pb = d nu_b / d theta_p, analytically.

    The Jacobian rows of masked / pinned parameters are zero by construction.
    """
    phi, alpha, gamma = effective_params(theta, t, cfg)
    pos = alpha >= 0.0

    dside = jnp.where(pos[None, :, None], t["histo_up"], t["histo_dn"])  # [S,A,B]
    delta = jnp.einsum("a,sab->sb", alpha, dside)
    raw = t["nominal"] + delta
    base = jnp.maximum(raw, EPS_RATE)
    unclipped = (raw > EPS_RATE).astype(theta.dtype)  # [S, B]

    lnfac = jnp.where(pos[None, :], alpha[None, :] * t["norm_lnup"],
                      -alpha[None, :] * t["norm_lndn"])
    dlnfac = jnp.where(pos[None, :], t["norm_lnup"], -t["norm_lndn"])  # [S, A]
    phis = jnp.maximum(phi, EPS_FREE)
    lnmult = lnfac.sum(axis=1) + t["free_map"] @ jnp.log(phis)
    mult = jnp.exp(lnmult)  # [S]

    gam = 1.0 + t["gamma_mask"] * (gamma[None, :] - 1.0)  # [S, B]
    nu_sb = base * mult[:, None] * gam
    nu_b = nu_sb.sum(axis=0)

    # d/d phi_f: sum_s nu_sb * M_sf / phi_f   (pinned rows -> 0)
    j_free = jnp.einsum("sb,sf->fb", nu_sb, t["free_map"]) / phis[:, None]
    j_free = j_free * t["free_mask"][:, None]

    # d/d alpha_a: sum_s [ dside_sab * mult_s * gam_sb * unclipped + nu_sb * dlnfac_sa ]
    add_term = jnp.einsum("sab,sb->ab", dside, mult[:, None] * gam * unclipped)
    norm_term = jnp.einsum("sb,sa->ab", nu_sb, dlnfac)
    j_alpha = (add_term + norm_term) * t["alpha_mask"][:, None]

    # d/d gamma_b (diagonal over bins): sum_s nu_sb * mask_sb / gam_sb
    j_gamma_diag = (nu_sb * t["gamma_mask"] / gam).sum(axis=0)
    j_gamma_diag = j_gamma_diag * (t["ctype"] > 0).astype(theta.dtype)
    j_gamma = jnp.diag(j_gamma_diag)

    jac = jnp.concatenate([j_free, j_alpha, j_gamma], axis=0)  # [P, B]
    return nu_b, jac


def poisson_nll_ref(nu_b, data, bin_mask):
    """Main-measurement Poisson NLL (theta-constant terms dropped)::

        sum_b mask_b * (nu_b - n_b * ln nu_b)
    """
    nu = jnp.maximum(nu_b, EPS_RATE)
    return jnp.sum(bin_mask * (nu - data * jnp.log(nu)))
