"""Pallas kernel: main-measurement Poisson NLL reduction.

Accumulates ``sum_b mask_b * (nu_b - n_b ln nu_b)`` over bin blocks into a
single scalar, the classic grid-accumulation pattern: block 0 initializes the
(1, 1) output, subsequent blocks add their partial sums. Constraint terms are
parameter-sized and stay in the L2 graph (see ``model.py``).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS_RATE


def _kernel(nu_ref, data_ref, mask_ref, out_ref):
    nu = jnp.maximum(nu_ref[...], EPS_RATE)
    partial = jnp.sum(mask_ref[...] * (nu - data_ref[...] * jnp.log(nu)))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[0, 0] = 0.0

    out_ref[0, 0] += partial


def poisson_nll_pallas(nu_b, data, bin_mask, cfg):
    """Pallas implementation of ``ref.poisson_nll_ref`` -> scalar."""
    bb = cfg.bin_block
    grid = (cfg.n_bins // bb,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), nu_b.dtype),
        interpret=True,
    )(nu_b, data, bin_mask)
    return out[0, 0]
