"""AOT compiler: lower the hypotest / MLE graphs to HLO text artifacts.

Run once at build time (``make artifacts``); the Rust coordinator loads the
results via the PJRT C API and Python never appears on the request path.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Emitted files (per shape class ``<name>`` in ``shapes.SHAPE_CLASSES``)::

    artifacts/hypotest_<name>.hlo.txt   4-fit asymptotic CLs program
    artifacts/mle_<name>.hlo.txt        single free-fit program
    artifacts/manifest.json             shapes/ordering contract for Rust
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .shapes import INPUT_ORDER, OUTPUT_ORDER, SHAPE_CLASSES, input_shapes  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big literals as ``constant({...})`` and xla_extension 0.5.1's
    parser silently materializes garbage for them (denormal soup, found the
    hard way — see DESIGN.md §5).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO text contains elided constants; artifact would be corrupt")
    return text


def lower_entry(fn, cfg):
    """jit + lower ``fn`` for shape class ``cfg`` and return HLO text."""
    shapes = input_shapes(cfg)
    specs = [jax.ShapeDtypeStruct(shapes[k], jnp.float64) for k in INPUT_ORDER]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build_all(out_dir: str, classes=None, use_pallas: bool = True,
              mu_test: float = 1.0, verbose: bool = True) -> dict:
    """Compile every artifact; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text",
        "dtype": "f64",
        "mu_test": mu_test,
        "use_pallas": use_pallas,
        "input_order": INPUT_ORDER,
        "output_order": OUTPUT_ORDER,
        "entries": {},
    }
    for name, cfg in (classes or SHAPE_CLASSES).items():
        cfg.validate()
        shapes = input_shapes(cfg)

        def hypo(*args, _cfg=cfg):
            return model.hypotest_graph(*args, cfg=_cfg, mu_test=mu_test,
                                        use_pallas=use_pallas)

        def mle(*args, _cfg=cfg):
            return model.mle_graph(*args, cfg=_cfg, use_pallas=use_pallas)

        for kind, fn in (("hypotest", hypo), ("mle", mle)):
            text = lower_entry(fn, cfg)
            fname = f"{kind}_{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            if verbose:
                print(f"  wrote {fname} ({len(text)} chars)")
            manifest["entries"][f"{kind}_{name}"] = {
                "file": fname,
                "kind": kind,
                "shape_class": cfg.to_dict(),
                "inputs": [
                    {"name": k, "shape": list(shapes[k]), "dtype": "f64"}
                    for k in INPUT_ORDER
                ],
            }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if verbose:
        print(f"  wrote manifest.json ({len(manifest['entries'])} entries)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (or a single .hlo.txt path whose "
                         "parent directory is used)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference graph instead of the "
                         "Pallas-kernel graph (ablation artifact)")
    ap.add_argument("--classes", default="",
                    help="comma-separated subset of shape classes")
    ap.add_argument("--mu-test", type=float, default=1.0)
    args = ap.parse_args()

    out_dir = args.out
    if out_dir.endswith(".txt"):
        out_dir = os.path.dirname(out_dir) or "."
    classes = None
    if args.classes:
        classes = {n: SHAPE_CLASSES[n] for n in args.classes.split(",")}
    build_all(out_dir, classes=classes, use_pallas=not args.no_pallas,
              mu_test=args.mu_test)


if __name__ == "__main__":
    main()
