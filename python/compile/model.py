"""L2: dense HistFactory statistical model, MLE fit and asymptotic hypotest.

Everything in this module is traceable jax that lowers to a single HLO
program per shape class (see ``aot.py``). Design constraints (DESIGN.md §5):

* **No LAPACK custom calls** — our Rust PJRT client has no jaxlib kernel
  registry, so the Newton linear solve is a conjugate-gradient loop built
  from matmuls.
* **No lgamma / erf opcodes** — theta-constant NLL terms are dropped, and
  the normal CDF uses a hand-rolled Abramowitz-Stegun erf polynomial
  (xla_extension 0.5.1's HLO parser predates the ``erf`` opcode).
* **Static control flow budgets** — fits run a fixed number of damped
  Fisher-scoring iterations (``cfg.max_newton``) with accept/reject masking,
  so runtime is deterministic per shape class.

The optimizer is damped Fisher scoring (Levenberg-Marquardt on the expected
information): theta_{k+1} = Proj[ theta_k - (J W J^T + C'' + lam D)^{-1} g ],
with J from the Pallas kernel (analytic Jacobian — no autodiff through
``pallas_call`` needed) and g = J (1 - n/nu) + constraint gradients.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import ref as kref
from .kernels.expected import expected_and_jacobian_pallas, expected_pallas
from .kernels.nll import poisson_nll_pallas

# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------

#: POI / free-norm lower bound: numerically zero but keeps ln(phi) finite,
#: which makes the bounded minimum at mu = 0 exact enough for qmu-tilde.
FREE_LO = 1e-10
GAMMA_LO = 1e-6
GAMMA_HI = 10.0
ALPHA_BOUND = 8.0
TINY = 1e-300


def erf_approx(x):
    """Abramowitz & Stegun 7.1.26 rational erf approximation (|err| < 1.5e-7).

    Built from mul/add/exp only — survives the HLO-text round trip to
    xla_extension 0.5.1 (the native ``erf`` opcode does not).
    """
    t = 1.0 / (1.0 + 0.3275911 * jnp.abs(x))
    poly = t * (0.254829592 + t * (-0.284496736 + t * (
        1.421413741 + t * (-1.453152027 + t * 1.061405429))))
    return jnp.sign(x) * (1.0 - poly * jnp.exp(-x * x))


def norm_cdf(x):
    """Standard normal CDF via :func:`erf_approx`."""
    return 0.5 * (1.0 + erf_approx(x / jnp.sqrt(2.0)))


# ---------------------------------------------------------------------------
# model pieces
# ---------------------------------------------------------------------------

def expected_and_jacobian(theta, t, cfg, use_pallas=True):
    """(nu_b[B], J[P,B]) via the Pallas kernel or the jnp oracle."""
    if use_pallas:
        return expected_and_jacobian_pallas(theta, t, cfg)
    return kref.expected_and_jacobian_ref(theta, t, cfg)


def expected_only(theta, t, cfg, use_pallas=True):
    """nu_b[B] without the Jacobian — the cheap forward pass used by NLL
    evaluations inside the optimizer's accept/reject test (Perf L2-1)."""
    if use_pallas:
        return expected_pallas(theta, t, cfg)
    return kref.expected_ref(theta, t, cfg).sum(axis=0)


def constraint_nll(theta, t, cfg, centers):
    """Constraint terms (theta-constant parts dropped).

    * alphas: 0.5 * (alpha - c_a)^2 (unit Gaussian), masked;
    * gammas, gauss (staterror): 0.5 * w_b * (gamma - g_c)^2;
    * gammas, poisson (shapesys): tau*gamma - m ln(tau*gamma), m = tau * g_c.
    """
    alpha_c, gamma_c = centers
    _, alpha, gamma = kref.effective_params(theta, t, cfg)
    ct, cs = t["ctype"], t["cscale"]

    na = 0.5 * jnp.sum(t["alpha_mask"] * (alpha - alpha_c) ** 2)

    is_g = (ct == 1.0).astype(theta.dtype)
    is_p = (ct == 2.0).astype(theta.dtype)
    gg = 0.5 * cs * (gamma - gamma_c) ** 2
    taug = jnp.maximum(cs * gamma, TINY)
    m_aux = cs * gamma_c
    gp = taug - m_aux * jnp.log(taug)
    return na + jnp.sum(is_g * gg + is_p * gp)


def full_nll(theta, t, cfg, centers, use_pallas=True):
    """Total NLL = main Poisson part + constraints (forward-only kernel)."""
    nu = expected_only(theta, t, cfg, use_pallas)
    if use_pallas:
        main = poisson_nll_pallas(nu, t["data"], t["bin_mask"], cfg)
    else:
        main = kref.poisson_nll_ref(nu, t["data"], t["bin_mask"])
    return main + constraint_nll(theta, t, cfg, centers)


def grad_and_fisher(theta, t, cfg, centers, fixed_mask, use_pallas=True):
    """Gradient and expected-information (Fisher) matrix, analytically.

    Fixed parameters get zeroed gradient rows and identity Hessian rows so the
    Newton step leaves them untouched.
    """
    alpha_c, gamma_c = centers
    f = cfg.n_free
    nu, jac = expected_and_jacobian(theta, t, cfg, use_pallas)
    nu_safe = jnp.maximum(nu, kref.EPS_RATE)

    resid = t["bin_mask"] * (1.0 - t["data"] / nu_safe)          # [B]
    w = t["bin_mask"] / nu_safe                                   # expected info weights
    grad = jac @ resid                                            # [P]
    fisher = (jac * w[None, :]) @ jac.T                           # [P, P]

    # constraints
    _, alpha, gamma = kref.effective_params(theta, t, cfg)
    ct, cs = t["ctype"], t["cscale"]
    g_alpha = t["alpha_mask"] * (alpha - alpha_c)
    h_alpha = t["alpha_mask"]
    is_g = (ct == 1.0).astype(theta.dtype)
    is_p = (ct == 2.0).astype(theta.dtype)
    m_aux = cs * gamma_c
    gamma_safe = jnp.maximum(gamma, GAMMA_LO)
    g_gamma = is_g * cs * (gamma - gamma_c) + is_p * (cs - m_aux / gamma_safe)
    h_gamma = is_g * cs + is_p * (m_aux / gamma_safe ** 2)

    cgrad = jnp.concatenate([jnp.zeros(f, theta.dtype), g_alpha, g_gamma])
    chess = jnp.concatenate([jnp.zeros(f, theta.dtype), h_alpha, h_gamma])
    grad = grad + cgrad
    fisher = fisher + jnp.diag(chess)

    live = 1.0 - fixed_mask
    grad = grad * live
    fisher = fisher * live[:, None] * live[None, :] + jnp.diag(fixed_mask)
    return grad, fisher


def cg_solve(h, g, iters):
    """Solve h x = g by fixed-iteration conjugate gradient (h SPD)."""
    x0 = jnp.zeros_like(g)

    def body(_, state):
        x, r, p, rs = state
        hp = h @ p
        denom = jnp.maximum(p @ hp, TINY)
        alpha = rs / denom
        x = x + alpha * p
        r = r - alpha * hp
        rs_new = r @ r
        beta = rs_new / jnp.maximum(rs, TINY)
        p = r + beta * p
        return x, r, p, rs_new

    x, _, _, _ = lax.fori_loop(0, iters, body, (x0, g, g, g @ g))
    return x


def param_bounds(t, cfg):
    """(lo[P], hi[P]) parameter box."""
    f, a, b = cfg.n_free, cfg.n_alpha, cfg.n_bins
    dt = t["data"].dtype
    lo = jnp.concatenate([
        jnp.full((f,), FREE_LO, dt),
        jnp.full((a,), -ALPHA_BOUND, dt),
        jnp.full((b,), GAMMA_LO, dt),
    ])
    hi = jnp.concatenate([
        jnp.full((f,), cfg.mu_max, dt),
        jnp.full((a,), ALPHA_BOUND, dt),
        jnp.full((b,), GAMMA_HI, dt),
    ])
    return lo, hi


def init_theta(t, cfg, mu_init=1.0):
    """Nominal starting point: frees at 1 (POI at mu_init), alphas 0, gammas 1."""
    f, a, b = cfg.n_free, cfg.n_alpha, cfg.n_bins
    dt = t["data"].dtype
    th = jnp.concatenate([
        jnp.ones((f,), dt), jnp.zeros((a,), dt), jnp.ones((b,), dt)])
    return th.at[0].set(mu_init)


def base_fixed_mask(t, cfg):
    """Structurally fixed parameters: pinned frees, masked alphas, type-0 gammas."""
    f_fixed = 1.0 - t["free_mask"]
    a_fixed = 1.0 - t["alpha_mask"]
    g_fixed = (t["ctype"] == 0.0).astype(t["data"].dtype)
    return jnp.concatenate([f_fixed, a_fixed, g_fixed])


#: Early-exit policy: stop after this many consecutive non-improving
#: (rejected or < tol) steps — the practical convergence signal for a
#: damped method (Perf L2-3: dynamic trip count via lax.while_loop).
STALL_LIMIT = 8
NLL_TOL = 1e-12


def fit(t, cfg, centers, fixed_mask, theta0, use_pallas=True):
    """Damped Fisher scoring with projection to bounds.

    Runs inside a `lax.while_loop` with an early exit once STALL_LIMIT
    consecutive iterations fail to improve the NLL by more than NLL_TOL
    (bounded by ``cfg.max_newton``).

    Returns (theta_hat, nll_hat, diagnostics[2] = (accepted_steps, |grad|)).
    """
    lo, hi = param_bounds(t, cfg)
    nll0 = full_nll(theta0, t, cfg, centers, use_pallas)
    dt = theta0.dtype

    def cond(state):
        _, _, _, _, it, stall = state
        return jnp.logical_and(it < cfg.max_newton, stall < STALL_LIMIT)

    def step(state):
        theta, nll, lam, accepted, it, stall = state
        g, h = grad_and_fisher(theta, t, cfg, centers, fixed_mask, use_pallas)
        damp = lam * jnp.maximum(jnp.diag(h), 1e-8)
        hd = h + jnp.diag(damp)
        dx = cg_solve(hd, g, cfg.cg_iters)
        theta_try = jnp.clip(theta - dx, lo, hi)
        nll_try = full_nll(theta_try, t, cfg, centers, use_pallas)
        ok = nll_try <= nll - 1e-12
        improved = nll - nll_try > NLL_TOL
        theta = jnp.where(ok, theta_try, theta)
        nll = jnp.where(ok, nll_try, nll)
        lam = jnp.where(ok, jnp.maximum(lam / 3.0, 1e-10),
                        jnp.minimum(lam * 8.0, 1e10))
        stall = jnp.where(improved, 0, stall + 1)
        return theta, nll, lam, accepted + ok.astype(dt), it + 1, stall

    theta, nll, _, accepted, _, _ = lax.while_loop(
        cond, step,
        (theta0, nll0, jnp.asarray(1e-3, dt), jnp.asarray(0.0, dt),
         jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32)))
    g, _ = grad_and_fisher(theta, t, cfg, centers, fixed_mask, use_pallas)
    # projected gradient: at a box-bound minimum the raw gradient need not
    # vanish — zero the components pushing out of the feasible box
    at_lo = jnp.logical_and(theta <= lo + 1e-12, g > 0)
    at_hi = jnp.logical_and(theta >= hi - 1e-12, g < 0)
    gp = jnp.where(jnp.logical_or(at_lo, at_hi), 0.0, g)
    return theta, nll, jnp.stack([accepted, jnp.sqrt(gp @ gp)])


def fit_mu_fixed(t, cfg, centers, mu_val, use_pallas=True):
    """Fit with the POI pinned at ``mu_val``."""
    fixed = base_fixed_mask(t, cfg).at[0].set(1.0)
    theta0 = init_theta(t, cfg, mu_init=mu_val)
    return fit(t, cfg, centers, fixed, theta0, use_pallas)


# ---------------------------------------------------------------------------
# hypothesis test (qmu-tilde + asymptotics, pyhf-compatible)
# ---------------------------------------------------------------------------

def hypotest_graph(data, nominal, histo_up, histo_dn, norm_lnup, norm_lndn,
                   free_map, free_mask, alpha_mask, gamma_mask, ctype, cscale,
                   bin_mask, *, cfg, mu_test=1.0, use_pallas=True):
    """Full asymptotic CLs hypothesis test; the AOT artifact entry point.

    Four bounded fits (observed free / observed mu=mu_test / background-only /
    Asimov mu=mu_test; the Asimov free NLL is exact at the generating point,
    saving a fifth fit) followed by the qmu-tilde asymptotic formulas of
    Cowan et al. [arXiv:1007.1727], matching ``pyhf.infer.hypotest``.

    Returns the OUTPUT_ORDER tuple of shapes.py.
    """
    t = {
        "data": data, "nominal": nominal, "histo_up": histo_up,
        "histo_dn": histo_dn, "norm_lnup": norm_lnup, "norm_lndn": norm_lndn,
        "free_map": free_map, "free_mask": free_mask,
        "alpha_mask": alpha_mask, "gamma_mask": gamma_mask,
        "ctype": ctype, "cscale": cscale, "bin_mask": bin_mask,
    }
    dt = data.dtype
    a, b = cfg.n_alpha, cfg.n_bins
    nominal_centers = (jnp.zeros((a,), dt), jnp.ones((b,), dt))

    # 1. observed, free POI
    th_free, nll_free, d1 = fit(t, cfg, nominal_centers,
                                base_fixed_mask(t, cfg),
                                init_theta(t, cfg), use_pallas)
    mu_hat = th_free[0]

    # 2. observed, mu = mu_test
    th_fix, nll_fixed, d2 = fit_mu_fixed(t, cfg, nominal_centers, mu_test,
                                         use_pallas)

    # 3. background-only fit (mu = 0) -> Asimov dataset + re-centered constraints
    th_bkg, _, d3 = fit_mu_fixed(t, cfg, nominal_centers, FREE_LO, use_pallas)
    nu_bkg, _ = expected_and_jacobian(th_bkg, t, cfg, use_pallas)
    _, alpha_bkg, gamma_bkg = kref.effective_params(th_bkg, t, cfg)
    asimov_centers = (alpha_bkg, gamma_bkg)
    t_asimov = dict(t, data=nu_bkg)

    # 4. Asimov, mu = mu_test. The Asimov free fit is exact at th_bkg: the
    #    Asimov data and constraint centers are generated there, so NLL_A is
    #    minimized at th_bkg (bounded mu_hat_A = 0).
    th_afix, nll_a_fixed, d4 = fit_mu_fixed(t_asimov, cfg, asimov_centers,
                                            mu_test, use_pallas)
    nll_a_free = full_nll(th_bkg, t_asimov, cfg, asimov_centers, use_pallas)

    # qmu-tilde
    qmu = jnp.where(mu_hat <= mu_test,
                    jnp.maximum(2.0 * (nll_fixed - nll_free), 0.0), 0.0)
    qmu_a = jnp.maximum(2.0 * (nll_a_fixed - nll_a_free), 0.0)

    sq = jnp.sqrt(jnp.maximum(qmu, 0.0))
    sqa = jnp.sqrt(jnp.maximum(qmu_a, TINY))

    # asymptotic p-values (qtilde piecewise form)
    in_range = qmu <= qmu_a
    clsb = jnp.where(in_range,
                     1.0 - norm_cdf(sq),
                     1.0 - norm_cdf((qmu + qmu_a) / (2.0 * sqa)))
    clb = jnp.where(in_range,
                    1.0 - norm_cdf(sq - sqa),
                    1.0 - norm_cdf((qmu - qmu_a) / (2.0 * sqa)))
    cls_obs = clsb / jnp.maximum(clb, TINY)

    nsig = jnp.array([-2.0, -1.0, 0.0, 1.0, 2.0], dt)
    cls_exp = (1.0 - norm_cdf(sqa - nsig)) / jnp.maximum(norm_cdf(nsig), TINY)

    diag = jnp.concatenate([d1, d2, d3, d4])
    return (cls_obs, cls_exp, qmu, qmu_a, mu_hat, nll_free, nll_fixed, diag)


def mle_graph(data, nominal, histo_up, histo_dn, norm_lnup, norm_lndn,
              free_map, free_mask, alpha_mask, gamma_mask, ctype, cscale,
              bin_mask, *, cfg, use_pallas=True):
    """Unconstrained MLE artifact entry point: (theta_hat[P], nll, diag[2])."""
    t = {
        "data": data, "nominal": nominal, "histo_up": histo_up,
        "histo_dn": histo_dn, "norm_lnup": norm_lnup, "norm_lndn": norm_lndn,
        "free_map": free_map, "free_mask": free_mask,
        "alpha_mask": alpha_mask, "gamma_mask": gamma_mask,
        "ctype": ctype, "cscale": cscale, "bin_mask": bin_mask,
    }
    dt = data.dtype
    centers = (jnp.zeros((cfg.n_alpha,), dt), jnp.ones((cfg.n_bins,), dt))
    return fit(t, cfg, centers, base_fixed_mask(t, cfg),
               init_theta(t, cfg), use_pallas)
