"""L2 build-time compiler package: dense HistFactory model, Pallas kernels,
shape classes and the AOT-to-HLO emitter (see `aot.build_all`)."""
