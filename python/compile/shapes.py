"""Shape-class configurations for AOT-compiled HistFactory hypotest artifacts.

Every artifact is compiled for a fixed *shape class*: padded tensor dimensions
plus optimizer budgets. The Rust coordinator pads any concrete workspace into
the smallest class that fits (see ``rust/src/histfactory/dense.rs``, which
mirrors this layout exactly; the contract is serialized into
``artifacts/manifest.json`` by ``aot.py``).

Parameter-vector layout (length ``n_params``)::

    theta = [ free norm-factors (POI = index 0) | alphas | gammas ]
              F entries                           A         B

Dense tensor inputs, in artifact argument order (all float64):

====================  ==========  ====================================
name                  shape       meaning
====================  ==========  ====================================
data                  [B]         observed main-measurement counts
nominal               [S, B]      per-sample nominal rates
histo_up              [S, A, B]   histosys delta (up - nominal)
histo_dn              [S, A, B]   histosys delta (nominal - down)
norm_lnup             [S, A]      ln(kappa+) normsys factors
norm_lndn             [S, A]      ln(kappa-) normsys factors
free_map              [S, F]      exponent of free norm f on sample s
free_mask             [F]         1 = parameter active, 0 = pinned at 1
alpha_mask            [A]         1 = alpha active, 0 = pinned at 0
gamma_mask            [S, B]      1 = gamma_b multiplies sample s bin b
ctype                 [B]         gamma constraint: 0 none, 1 gauss, 2 poisson
cscale                [B]         gauss: precision 1/delta^2; poisson: tau
bin_mask              [B]         1 = real bin, 0 = padding
====================  ==========  ====================================

Constraint centers default to nominal (alpha = 0, gamma = 1); the Asimov
branch of the hypotest graph re-centers them at the background-only fit
internally, so they are not runtime inputs.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ShapeConfig:
    """A fixed shape class for one AOT artifact."""

    name: str
    n_bins: int  # B, padded to a multiple of bin_block
    n_samples: int  # S (signal is sample 0)
    n_alpha: int  # A: constrained interpolation parameters
    n_free: int  # F: free norm factors, POI first
    max_newton: int = 48  # damped Fisher-scoring iteration budget
    cg_iters: int = 64  # conjugate-gradient solve budget per step
    bin_block: int = 16  # Pallas block size along the bin axis (Perf L1-2:
    #   whole-row blocks — VMEM comfortably holds a full shape-class row,
    #   so one grid step minimizes interpret-loop overhead on CPU and
    #   HBM->VMEM round trips on TPU)
    mu_max: float = 10.0  # POI upper bound (lower bound 0 => qmu-tilde)

    @property
    def n_params(self) -> int:
        return self.n_free + self.n_alpha + self.n_bins

    def validate(self) -> None:
        assert self.n_bins % self.bin_block == 0, "bins must tile evenly"
        assert self.n_free >= 1, "need at least the POI"
        assert self.n_samples >= 2, "need signal + >=1 background"

    def to_dict(self) -> dict:
        d = asdict(self)
        d["n_params"] = self.n_params
        return d


#: Shape classes mirroring the three published analyses of the paper's
#: Table 1 plus a small quickstart class. Complexity tiers are calibrated so
#: the per-patch fit cost *ordering* matches the paper (1Lbb heavy, 2L0J
#: light, stau medium); see DESIGN.md section 4 (substitutions).
#:
#: ``n_samples`` counts dense **(channel, sample) rows** — normsys /
#: normfactor / gamma application is per channel in pyhf, so each channel's
#: samples get their own rows (padded rows have nominal = 0 and are inert).
SHAPE_CLASSES = {
    # Eur. Phys. J. C 80 (2020) 691 - electroweakino 1Lbb, 125 patches
    # (8 channels x up to 6 samples)
    "1Lbb": ShapeConfig(
        name="1Lbb", n_bins=80, n_samples=48, n_alpha=48, n_free=2,
        max_newton=48, cg_iters=64, bin_block=80,
    ),
    # JHEP 06 (2020) 46 - squarks/gluinos same-sign leptons, 76 patches
    # (4 channels x up to 4 samples)
    "2L0J": ShapeConfig(
        name="2L0J", n_bins=32, n_samples=16, n_alpha=16, n_free=2,
        max_newton=40, cg_iters=48, bin_block=32,
    ),
    # Phys. Rev. D 101 (2020) 032009 - direct stau, 57 patches
    # (5 channels x up to 4 samples)
    "stau": ShapeConfig(
        name="stau", n_bins=48, n_samples=20, n_alpha=28, n_free=2,
        max_newton=44, cg_iters=56, bin_block=48,
    ),
    # Tiny class for the quickstart example and fast tests
    # (2 channels x up to 3 samples)
    "quickstart": ShapeConfig(
        name="quickstart", n_bins=16, n_samples=6, n_alpha=6, n_free=2,
        max_newton=32, cg_iters=24,
    ),
}

#: Artifact input order; must match model.hypotest_graph's signature and the
#: Rust marshaller.
INPUT_ORDER = [
    "data", "nominal", "histo_up", "histo_dn", "norm_lnup", "norm_lndn",
    "free_map", "free_mask", "alpha_mask", "gamma_mask", "ctype", "cscale",
    "bin_mask",
]

#: Artifact output order (flat tuple).
OUTPUT_ORDER = [
    "cls_obs",      # scalar
    "cls_exp",      # [5] expected band, N sigma in (-2,-1,0,1,2)
    "qmu",          # scalar observed test statistic (tilde)
    "qmu_A",        # scalar Asimov test statistic
    "mu_hat",       # scalar best-fit POI (bounded >= 0)
    "nll_free",     # scalar NLL at free fit
    "nll_fixed",    # scalar NLL at mu = mu_test
    "diag",         # [8] fit diagnostics (accepted steps / |grad| per fit)
]


def input_shapes(cfg: ShapeConfig) -> dict:
    """Map input name -> shape tuple for a shape class."""
    b, s, a, f = cfg.n_bins, cfg.n_samples, cfg.n_alpha, cfg.n_free
    return {
        "data": (b,),
        "nominal": (s, b),
        "histo_up": (s, a, b),
        "histo_dn": (s, a, b),
        "norm_lnup": (s, a),
        "norm_lndn": (s, a),
        "free_map": (s, f),
        "free_mask": (f,),
        "alpha_mask": (a,),
        "gamma_mask": (s, b),
        "ctype": (b,),
        "cscale": (b,),
        "bin_mask": (b,),
    }
