"""Synthetic dense-tensor workspace generator (test + calibration fixtures).

Generates physically plausible dense HistFactory tensors for a shape class:
falling background spectra, a signal bump (sample 0), per-sample normsys and
histosys variations, staterror gammas. Deterministic per seed.

The Rust pallet generator (``rust/src/pallet``) produces full HistFactory
*JSON* workspaces whose dense compilation must match this layout; this module
is the light-weight python-side equivalent used by the pytest suite.
"""

import numpy as np


def make_tensors(cfg, seed=0, signal_scale=1.0, active_bins=None,
                 active_alpha=None, data_mu=0.0):
    """Build a dense tensor dict for ``cfg``.

    ``data_mu`` injects signal at that strength into the observed data
    (Asimov-style, rounded to integers to emulate counts).
    """
    rng = np.random.default_rng(seed)
    s_, a_, b_, f_ = cfg.n_samples, cfg.n_alpha, cfg.n_bins, cfg.n_free
    nb = b_ if active_bins is None else active_bins
    na = a_ if active_alpha is None else active_alpha
    assert nb <= b_ and na <= a_

    bin_mask = np.zeros(b_)
    bin_mask[:nb] = 1.0
    alpha_mask = np.zeros(a_)
    alpha_mask[:na] = 1.0

    # backgrounds: falling exponentials with different slopes; signal: bump
    nominal = np.zeros((s_, b_))
    x = np.linspace(0.0, 1.0, nb)
    center = rng.uniform(0.3, 0.7)
    width = rng.uniform(0.08, 0.2)
    nominal[0, :nb] = signal_scale * 8.0 * np.exp(-0.5 * ((x - center) / width) ** 2)
    for s in range(1, s_):
        norm = rng.uniform(30.0, 120.0) / s
        slope = rng.uniform(1.0, 4.0)
        nominal[s, :nb] = norm * np.exp(-slope * x) + rng.uniform(0.5, 2.0)

    # normsys: each alpha touches a random subset of background samples
    norm_lnup = np.zeros((s_, a_))
    norm_lndn = np.zeros((s_, a_))
    histo_up = np.zeros((s_, a_, b_))
    histo_dn = np.zeros((s_, a_, b_))
    for a in range(na):
        if a % 2 == 0:  # normsys
            for s in range(1, s_):
                if rng.random() < 0.6:
                    kap = 1.0 + rng.uniform(0.02, 0.25)
                    norm_lnup[s, a] = np.log(kap)
                    norm_lndn[s, a] = np.log(1.0 / kap)
        else:  # histosys: smooth shape tilt, small vs nominal
            for s in range(1, s_):
                if rng.random() < 0.5:
                    tilt = rng.uniform(-0.15, 0.15)
                    shape = tilt * (x - 0.5) * nominal[s, :nb]
                    histo_up[s, a, :nb] = shape
                    histo_dn[s, a, :nb] = -shape * rng.uniform(0.7, 1.1)

    # free norms: POI on signal; one floating background norm if f_ > 1
    free_map = np.zeros((s_, f_))
    free_mask = np.zeros(f_)
    free_map[0, 0] = 1.0
    free_mask[0] = 1.0
    if f_ > 1 and s_ > 1:
        free_map[1, 1] = 1.0
        free_mask[1] = 1.0

    # staterror gammas (gauss) on every active bin, applied to backgrounds
    gamma_mask = np.zeros((s_, b_))
    gamma_mask[1:, :nb] = 1.0
    ctype = np.zeros(b_)
    cscale = np.ones(b_)
    ctype[:nb] = 1.0
    rel = rng.uniform(0.01, 0.08, size=nb)  # relative MC stat uncertainty
    cscale[:nb] = 1.0 / rel**2

    bkg = nominal[1:, :].sum(axis=0)
    lam = bkg + data_mu * nominal[0, :]
    data = np.round(lam * bin_mask).astype(float)

    t = {
        "data": data, "nominal": nominal, "histo_up": histo_up,
        "histo_dn": histo_dn, "norm_lnup": norm_lnup, "norm_lndn": norm_lndn,
        "free_map": free_map, "free_mask": free_mask,
        "alpha_mask": alpha_mask, "gamma_mask": gamma_mask,
        "ctype": ctype, "cscale": cscale, "bin_mask": bin_mask,
    }
    return {k: np.asarray(v, dtype=np.float64) for k, v in t.items()}


def random_theta(cfg, t, seed=1, spread=0.3):
    """A random parameter point inside the bounds (for kernel sweeps)."""
    rng = np.random.default_rng(seed)
    f_, a_, b_ = cfg.n_free, cfg.n_alpha, cfg.n_bins
    phi = rng.uniform(0.2, 2.0, size=f_)
    alpha = rng.normal(0.0, spread, size=a_)
    gamma = rng.uniform(0.8, 1.2, size=b_)
    return np.concatenate([phi, alpha, gamma]).astype(np.float64)
