//! Fitting-as-a-service over a real socket: the "FaaS analysis facility"
//! blueprint of the paper's §2.3 as a runnable system.
//!
//! One process hosts the service (registry + endpoint + PJRT workers) behind
//! a TCP protocol using the coordinator's framed JSON codec; a client
//! process submits fit tasks and polls for results — the same
//! register/run/get_result flow as Listing 1, but across a process boundary.
//!
//! Run (single process, spawns its own client thread):
//!     cargo run --release --example faas_service
//! Or split:
//!     cargo run --release --example faas_service -- serve 127.0.0.1:9123
//!     cargo run --release --example faas_service -- client 127.0.0.1:9123
//!
//! Protocol (one frame per message, see coordinator::serialize):
//!     -> {"action": "submit", "payload": {...fit task...}}   <- {"task": id}
//!     -> {"action": "result", "task": id}                    <- {"state": .., "result"?: ..}
//!     -> {"action": "shutdown"}                              <- {"ok": true}

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use pyhf_faas::coordinator::serialize::{decode, encode, frame_len};
use pyhf_faas::coordinator::{
    fitops, Endpoint, EndpointConfig, ExecutorConfig, FaasClient, Service,
};
use pyhf_faas::infer::results::PointResult;
use pyhf_faas::pallet::{self, library};
use pyhf_faas::runtime::{default_artifact_dir, Engine};
use pyhf_faas::util::json::Json;

fn read_frame(stream: &mut TcpStream) -> std::io::Result<Option<Json>> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(total) = frame_len(&buf) {
            if buf.len() >= total {
                return Ok(decode(&buf[..total]).ok());
            }
        } else if buf.len() >= 8 {
            return Ok(None); // bad magic
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Ok(None);
        }
        buf.extend_from_slice(&chunk[..n]);
    }
}

fn write_frame(stream: &mut TcpStream, v: &Json) -> std::io::Result<()> {
    stream.write_all(&encode(v))
}

fn serve(addr: &str) -> Result<(), String> {
    // fail fast if the PJRT engine is stubbed out (default build without the
    // vendored xla crate) — otherwise every worker dies at init and clients
    // poll forever
    Engine::cpu().map_err(|e| format!("faas_service needs the PJRT engine: {e}"))?;
    let svc = Service::new();
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("tcp-facility")
            .with_executor(ExecutorConfig {
                max_blocks: 1,
                nodes_per_block: 1,
                workers_per_node: 2,
                parallelism: 1.0,
                poll: Duration::from_millis(2),
            })
            .with_worker_init(fitops::pjrt_worker_init(default_artifact_dir())),
    );
    let client = FaasClient::new(svc.clone());
    let fit_fn = client.register_function("fit_patch", fitops::fit_patch_handler());

    let listener = TcpListener::bind(addr).map_err(|e| e.to_string())?;
    println!("[service] fitting facility listening on {addr}");

    let mut shutdown = false;
    while !shutdown {
        let (mut stream, peer) = listener.accept().map_err(|e| e.to_string())?;
        println!("[service] connection from {peer}");
        loop {
            let msg = match read_frame(&mut stream) {
                Ok(Some(m)) => m,
                _ => break,
            };
            let action = msg.get("action").and_then(|a| a.as_str()).unwrap_or("");
            let reply = match action {
                "submit" => match msg.get("payload") {
                    Some(p) => match client.run(p.clone(), ep.id, fit_fn) {
                        Ok(id) => Json::obj(vec![("task", Json::num(id as f64))]),
                        Err(e) => Json::obj(vec![("error", Json::str(e))]),
                    },
                    None => Json::obj(vec![("error", Json::str("missing payload"))]),
                },
                "result" => {
                    let id = msg.get("task").and_then(|t| t.as_f64()).unwrap_or(-1.0) as u64;
                    match client.get_result(id) {
                        Some(Ok(v)) => Json::obj(vec![
                            ("state", Json::str("success")),
                            ("result", v),
                        ]),
                        Some(Err(e)) => Json::obj(vec![
                            ("state", Json::str("failed")),
                            ("error", Json::str(e)),
                        ]),
                        None => Json::obj(vec![(
                            "state",
                            Json::str(
                                client.status(id).map(|s| s.as_str()).unwrap_or("unknown"),
                            ),
                        )]),
                    }
                }
                "shutdown" => {
                    shutdown = true;
                    let _ = write_frame(&mut stream, &Json::obj(vec![("ok", Json::Bool(true))]));
                    break;
                }
                other => Json::obj(vec![("error", Json::str(format!("bad action '{other}'")))]),
            };
            if write_frame(&mut stream, &reply).is_err() {
                break;
            }
        }
        println!("[service] connection closed");
    }
    // scheduler accounting: queue wait + service times land on the service
    // hub; affinity and block counters land on the endpoint hub
    let sm = svc.metrics.snapshot();
    let em = ep.metrics_snapshot();
    println!(
        "[service] {} tasks ({} failed) | mean queue wait {:.3} s | mean fit {:.3} s",
        sm.completed + sm.failed,
        sm.failed,
        sm.mean_wait_s,
        sm.mean_service_s
    );
    println!(
        "[service] scheduler: affinity {} hit / {} miss ({:.0}% warm) | batches {} ({} fits, {} deduped) | blocks +{} -{}",
        em.affinity_hits,
        em.affinity_misses,
        em.affinity_hit_rate() * 100.0,
        sm.batches,
        sm.batched_tasks,
        sm.dedup_hits,
        em.blocks_provisioned,
        em.blocks_released
    );
    ep.shutdown();
    println!("[service] shut down");
    Ok(())
}

fn rpc(stream: &mut TcpStream, msg: &Json) -> Result<Json, String> {
    write_frame(stream, msg).map_err(|e| e.to_string())?;
    read_frame(stream).map_err(|e| e.to_string())?.ok_or_else(|| "connection closed".into())
}

fn run_client(addr: &str) -> Result<(), String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    println!("[client] connected to {addr}");

    // submit the quickstart pallet's patches
    let pallet = pallet::generate(&library::config_quickstart());
    let mut tasks = Vec::new();
    for patch in &pallet.patchset.patches {
        let payload = fitops::patch_payload(&pallet.bkg_workspace, patch, None)?;
        let reply = rpc(&mut stream, &Json::obj(vec![
            ("action", Json::str("submit")),
            ("payload", payload),
        ]))?;
        let id = reply.get("task").and_then(|t| t.as_f64()).ok_or("submit failed")? as u64;
        tasks.push((patch.name.clone(), id));
    }
    println!("[client] submitted {} fit tasks", tasks.len());

    // poll (Listing-1 style)
    let mut done = 0;
    let mut pending: Vec<(String, u64)> = tasks;
    while !pending.is_empty() {
        let mut still = Vec::new();
        for (name, id) in pending {
            let reply = rpc(&mut stream, &Json::obj(vec![
                ("action", Json::str("result")),
                ("task", Json::num(id as f64)),
            ]))?;
            match reply.get("state").and_then(|s| s.as_str()) {
                Some("success") => {
                    done += 1;
                    let point = PointResult::from_json(reply.get("result").unwrap())
                        .ok_or("malformed result")?;
                    println!(
                        "Task {name} complete, there are {done} results now (CLs = {:.4})",
                        point.cls_obs
                    );
                }
                Some("failed") => return Err(format!("task {name} failed")),
                _ => still.push((name, id)),
            }
        }
        pending = still;
        if !pending.is_empty() {
            std::thread::sleep(Duration::from_millis(50));
        }
    }
    let _ = rpc(&mut stream, &Json::obj(vec![("action", Json::str("shutdown"))]));
    println!("[client] all results in; asked service to shut down");
    Ok(())
}

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("serve") => serve(args.get(1).map(|s| s.as_str()).unwrap_or("127.0.0.1:9123")),
        Some("client") => run_client(args.get(1).map(|s| s.as_str()).unwrap_or("127.0.0.1:9123")),
        _ => {
            // demo mode: service in a thread + client in main
            let addr = "127.0.0.1:9217";
            let server = std::thread::spawn(move || serve(addr));
            std::thread::sleep(Duration::from_millis(300));
            run_client(addr)?;
            server.join().map_err(|_| "server panicked".to_string())??;
            Ok(())
        }
    }
}
