//! Quickstart: one hypothesis test, three ways.
//!
//! 1. direct PJRT execution of the AOT artifact (the production hot path);
//! 2. the native-Rust baseline fitter (cross-check);
//! 3. a fit served through the funcX-style coordinator.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::time::{Duration, Instant};

use pyhf_faas::coordinator::{
    fitops, Endpoint, EndpointConfig, ExecutorConfig, FaasClient, Service,
};
use pyhf_faas::fitter::NativeFitter;
use pyhf_faas::histfactory::{dense, Workspace};
use pyhf_faas::infer::results::PointResult;
use pyhf_faas::pallet::{self, library};
use pyhf_faas::runtime::{default_artifact_dir, Engine, Manifest};

fn main() -> Result<(), String> {
    let dir = default_artifact_dir();
    let manifest = Manifest::load(&dir)?;

    // --- build a tiny analysis: background workspace + one signal patch ----
    let pallet = pallet::generate(&library::config_quickstart());
    let patch = &pallet.patchset.patches[0];
    println!(
        "pallet '{}': {} patches; testing '{}' (m1={}, m2={})\n",
        pallet.config.name,
        pallet.patchset.len(),
        patch.name,
        patch.values[0],
        patch.values[1]
    );

    let ws =
        Workspace::from_json(&patch.apply_to(&pallet.bkg_workspace).map_err(|e| e.to_string())?)
            .map_err(|e| e.to_string())?;
    let classes = manifest.classes();
    let class = dense::pick_class(&ws, &classes).map_err(|e| e.to_string())?;
    let model = dense::compile(&ws, class).map_err(|e| e.to_string())?;
    println!(
        "dense model: class '{}' (B={}, S={}, A={}, P={})\n",
        class.name,
        class.n_bins,
        class.n_samples,
        class.n_alpha,
        class.n_params()
    );

    // --- 1. PJRT artifact (the request-path implementation) ---------------
    let engine = Engine::cpu().map_err(|e| e.to_string())?;
    let entry = manifest.hypotest(&class.name).ok_or("missing artifact")?;
    let t0 = Instant::now();
    let compiled = engine.load(entry, &dir).map_err(|e| e.to_string())?;
    let compile_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let pjrt = compiled.hypotest(&model).map_err(|e| e.to_string())?;
    let fit_s = t0.elapsed().as_secs_f64();
    println!(
        "[pjrt]   CLs_obs = {:.5}  mu_hat = {:.3}  qmu = {:.3}  (compile {:.2} s, fit {:.3} s)",
        pjrt.cls_obs, pjrt.mu_hat, pjrt.qmu, compile_s, fit_s
    );

    // --- 2. native baseline ------------------------------------------------
    let t0 = Instant::now();
    let native = NativeFitter::new(&model).hypotest(1.0);
    println!(
        "[native] CLs_obs = {:.5}  mu_hat = {:.3}  qmu = {:.3}  (fit {:.3} s)",
        native.cls_obs,
        native.mu_hat,
        native.qmu,
        t0.elapsed().as_secs_f64()
    );
    assert!((pjrt.cls_obs - native.cls_obs).abs() < 0.02, "cross-check failed");

    // --- 3. through the FaaS coordinator -----------------------------------
    let svc = Service::new();
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("quickstart-endpoint")
            .with_executor(ExecutorConfig {
                max_blocks: 1,
                nodes_per_block: 1,
                workers_per_node: 1,
                parallelism: 1.0,
                poll: Duration::from_millis(2),
            })
            .with_worker_init(fitops::pjrt_worker_init(dir)),
    );
    let fxc = FaasClient::new(svc.clone());
    let fit_fn = fxc.register_function("fit_patch", fitops::fit_patch_handler());
    let payload = fitops::patch_payload(&pallet.bkg_workspace, patch, None)?;
    let task = fxc.run(payload, ep.id, fit_fn)?;
    let result = fxc.wait(task, Duration::from_secs(600))?;
    let point = PointResult::from_json(&result).ok_or("malformed result")?;
    println!(
        "[faas]   CLs_obs = {:.5}  mu_hat = {:.3}  ({})",
        point.cls_obs,
        point.mu_hat,
        if point.excluded() { "EXCLUDED at 95% CL" } else { "allowed" }
    );
    println!("\nexpected CLs band (-2..+2 sigma): {:?}", point.cls_exp);
    ep.shutdown();
    println!("\nquickstart OK: all three paths agree.");
    Ok(())
}
