//! The paper's headline workflow (§3, Listing 2): simultaneously fit the 125
//! signal-hypothesis patches of the 1Lbb electroweakino search through the
//! FaaS fabric, streaming per-task completions, and report the wall time.
//!
//! Run: `cargo run --release --example scan_1lbb -- [n_workers] [max_blocks] [limit]`
//!
//! The output format replicates the paper's Listing 2 (task completion
//! stream + wall-time summary). This is also the end-to-end validation run
//! recorded in EXPERIMENTS.md.

use std::time::Duration;

use pyhf_faas::coordinator::{
    fitops, run_scan, Endpoint, EndpointConfig, ExecutorConfig, FaasClient, ScanOptions, Service,
    SimSlurmProvider,
};
use pyhf_faas::infer::results::upper_limit_on_axis;
use pyhf_faas::pallet::{self, library};
use pyhf_faas::runtime::default_artifact_dir;
use pyhf_faas::util::stats::Summary;

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let max_blocks: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let limit: Option<usize> = args.get(2).and_then(|s| s.parse().ok());

    println!("generating 1Lbb pallet (125 signal patches, 8 channels x 9 bins) ...");
    let pallet = pallet::generate(&library::config_1lbb());

    let svc = Service::new();
    println!(
        "starting funcX-style endpoint: max_blocks={max_blocks}, nodes_per_block=1, {workers} workers/node"
    );
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("river-analog")
            .with_executor(ExecutorConfig {
                max_blocks,
                nodes_per_block: 1,
                workers_per_node: workers,
                parallelism: 1.0,
                poll: Duration::from_millis(2),
            })
            .with_provider(Box::new(SimSlurmProvider::laptop_scale(0x1bb)))
            .with_worker_init(fitops::pjrt_worker_init(default_artifact_dir())),
    );
    let client = FaasClient::new(svc.clone());
    let fit_fn = client.register_function("fit_patch", fitops::fit_patch_handler());

    println!("prepare: waiting-for-nodes");
    let opts = ScanOptions { verbose: true, limit, ..Default::default() };
    let scan = run_scan(&client, ep.id, fit_fn, &pallet, &opts)?;

    // Listing-2 style summary
    let mins = (scan.wall_seconds / 60.0).floor();
    let secs = scan.wall_seconds - 60.0 * mins;
    println!("\nreal    {}m{:.3}s", mins as u64, secs);

    let m = svc.metrics.snapshot();
    let fit_times: Vec<f64> = scan.points.iter().map(|p| p.fit_seconds).collect();
    let fits = Summary::of(&fit_times);
    println!("\n=== scan summary ===");
    println!("patches fit           : {}", scan.points.len());
    println!("wall time             : {:.1} s", scan.wall_seconds);
    println!(
        "sum of fit times      : {:.1} s  (single-worker equivalent)",
        scan.total_fit_seconds()
    );
    println!(
        "per-fit service time  : {:.3} ± {:.3} s (min {:.3}, max {:.3})",
        fits.mean, fits.std, fits.min, fits.max
    );
    println!(
        "parallel speedup      : {:.1}x",
        scan.total_fit_seconds() / scan.wall_seconds
    );
    println!("blocks provisioned    : {}", ep.blocks());
    println!("mean queue wait       : {:.3} s", m.mean_wait_s);
    println!("excluded at 95% CL    : {} / {}", scan.n_excluded(), scan.points.len());
    if let Some(ul) = upper_limit_on_axis(&scan.points, 0.0) {
        println!("interpolated m1 limit : {ul:.0} GeV (m2 = 0)");
    }
    ep.shutdown();
    Ok(())
}
