//! Table-1 style multi-analysis comparison: fit all three published-analysis
//! tiers (1Lbb, 2L0J, stau) distributed vs single-worker, on this host.
//!
//! Run: `cargo run --release --example multi_analysis -- [workers] [patches_per_analysis]`
//!
//! The full paper-topology replay (RIVER scale, 10 trials) lives in
//! `cargo bench --bench table1`; this example runs *real* fits both ways
//! and prints the measured table for this machine.

use std::time::Duration;

use pyhf_faas::coordinator::{
    fitops, run_scan, Endpoint, EndpointConfig, ExecutorConfig, FaasClient, ScanOptions, Service,
};
use pyhf_faas::pallet::{self, library};
use pyhf_faas::runtime::default_artifact_dir;

fn scan_with(
    workers: usize,
    max_blocks: usize,
    pallet: &pyhf_faas::pallet::Pallet,
    limit: Option<usize>,
) -> Result<pyhf_faas::infer::results::ScanResult, String> {
    let svc = Service::new();
    let ep = Endpoint::start(
        svc.clone(),
        EndpointConfig::new("bench-ep")
            .with_executor(ExecutorConfig {
                max_blocks,
                nodes_per_block: 1,
                workers_per_node: workers,
                parallelism: 1.0,
                poll: Duration::from_millis(2),
            })
            .with_worker_init(fitops::pjrt_worker_init(default_artifact_dir())),
    );
    let client = FaasClient::new(svc.clone());
    let f = client.register_function("fit_patch", fitops::fit_patch_handler());
    let scan = run_scan(&client, ep.id, f, pallet, &ScanOptions { limit, ..Default::default() });
    ep.shutdown();
    scan
}

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let limit: Option<usize> = args.get(1).and_then(|s| s.parse().ok()).or(Some(16));

    println!("measured on this host (distributed = {workers} workers x 2 blocks, single = 1 worker):\n");
    println!(
        "{:<34} {:>8} {:>16} {:>18} {:>9}",
        "Analysis", "Patches", "Wall time (s)", "Single worker (s)", "Speedup"
    );

    for cfg in [library::config_1lbb(), library::config_2l0j(), library::config_stau()] {
        let pallet = pallet::generate(&cfg);
        let dist = scan_with(workers, 2, &pallet, limit)?;
        let single = scan_with(1, 1, &pallet, limit)?;
        let paper = pyhf_faas::sim::PAPER_TABLE1
            .iter()
            .find(|r| r.analysis == cfg.name)
            .unwrap();
        println!(
            "{:<34} {:>8} {:>16.2} {:>18.2} {:>8.1}x   (paper: {:.1} ± {:.1} vs {:.0} s)",
            format!("{} ({})", cfg.name, paper_label(&cfg.name)),
            dist.points.len(),
            dist.wall_seconds,
            single.wall_seconds,
            single.wall_seconds / dist.wall_seconds,
            paper.wall_mean_s,
            paper.wall_std_s,
            paper.single_node_s,
        );
        // sanity: same physics both ways
        for (a, b) in dist.points.iter().zip(single.points.iter()) {
            assert!((a.cls_obs - b.cls_obs).abs() < 1e-9, "{}: nondeterministic CLs", a.patch);
        }
    }
    println!("\n(per-patch model complexity drives the tier ordering, as in the paper's Table 1;");
    println!(" run `cargo bench --bench table1` for the RIVER-topology replay with 10 trials)");
    Ok(())
}

fn paper_label(name: &str) -> &'static str {
    match name {
        "1Lbb" => "Eur. Phys. J. C 80 (2020) 691",
        "2L0J" => "JHEP 06 (2020) 46",
        "stau" => "Phys. Rev. D 101 (2020) 032009",
        _ => "",
    }
}
