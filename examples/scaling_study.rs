//! Block-scaling study (paper §3): how wall time responds to `max_blocks`
//! and worker count, measured with real fits on this host AND replayed on
//! the paper's RIVER topology via the discrete-event simulator.
//!
//! Run: `cargo run --release --example scaling_study -- [patches]`

use std::time::Duration;

use pyhf_faas::coordinator::{
    fitops, run_scan, Endpoint, EndpointConfig, ExecutorConfig, FaasClient, ScanOptions, Service,
};
use pyhf_faas::pallet::{self, library};
use pyhf_faas::runtime::default_artifact_dir;
use pyhf_faas::sim;

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let patches: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);

    let pallet = pallet::generate(&library::config_2l0j());
    println!("analysis tier: {} ({} patches used)\n", pallet.config.name, patches);

    // --- measured on this host: workers sweep ------------------------------
    println!("== measured on this host (real PJRT fits) ==");
    println!("{:<26} {:>12} {:>14} {:>10}", "topology", "wall (s)", "sum fits (s)", "speedup");
    let mut measured_service: Vec<f64> = Vec::new();
    for (blocks, workers) in [(1usize, 1usize), (1, 2), (2, 1), (2, 2)] {
        let svc = Service::new();
        let ep = Endpoint::start(
            svc.clone(),
            EndpointConfig::new("sweep")
                .with_executor(ExecutorConfig {
                    max_blocks: blocks,
                    nodes_per_block: 1,
                    workers_per_node: workers,
                    parallelism: 1.0,
                    poll: Duration::from_millis(2),
                })
                .with_worker_init(fitops::pjrt_worker_init(default_artifact_dir())),
        );
        let client = FaasClient::new(svc.clone());
        let f = client.register_function("fit_patch", fitops::fit_patch_handler());
        let scan = run_scan(
            &client,
            ep.id,
            f,
            &pallet,
            &ScanOptions { limit: Some(patches), ..Default::default() },
        )?;
        println!(
            "{:<26} {:>12.2} {:>14.2} {:>9.1}x",
            format!("{blocks} blocks x {workers} workers"),
            scan.wall_seconds,
            scan.total_fit_seconds(),
            scan.total_fit_seconds() / scan.wall_seconds
        );
        if measured_service.is_empty() {
            measured_service = scan.points.iter().map(|p| p.fit_seconds).collect();
        }
        ep.shutdown();
    }

    // --- replayed at paper scale -------------------------------------------
    let paper = sim::PAPER_TABLE1.iter().find(|r| r.analysis == "2L0J").unwrap();
    let full: Vec<f64> = (0..paper.patches)
        .map(|i| measured_service[i % measured_service.len()])
        .collect();
    let mult = sim::calibrate_multiplier(&full, paper.single_node_s);
    let scaled: Vec<f64> = full.iter().map(|s| s * mult).collect();

    println!("\n== DES replay at RIVER scale (x{mult:.0} work multiplier, 10 trials) ==");
    println!("{:<26} {:>16}", "topology", "wall (s)");
    for (b, s) in sim::block_scaling(&scaled, &[1, 2, 4, 8], 10, 0x5ca1e) {
        println!("{:<26} {:>10.1} ± {:>4.1}", format!("{b} blocks x 24 workers"), s.mean, s.std);
    }
    println!("\npaper reference: {} patches, {:.1} ± {:.1} s at 4 blocks; {} s single node",
        paper.patches, paper.wall_mean_s, paper.wall_std_s, paper.single_node_s);
    println!("paper §3 also reports an isolated 125-patch 1Lbb run at 76 s — reproduced in bench 'scaling'.");
    Ok(())
}
