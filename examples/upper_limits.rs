//! Extension workloads from the paper's conclusions: upper-limit scans,
//! toy-based CLs, and a two-analysis statistical combination.
//!
//! Run: `cargo run --release --example upper_limits`

use pyhf_faas::fitter::{hypotest_toys, NativeFitter};
use pyhf_faas::histfactory::{combine, dense, prefix_channels, Workspace};
use pyhf_faas::infer::{default_mu_grid, upper_limit_scan};
use pyhf_faas::pallet::{self, library};
use pyhf_faas::runtime::{default_artifact_dir, Manifest};

fn main() -> Result<(), String> {
    let manifest = Manifest::load(&default_artifact_dir())?;
    let classes = manifest.classes();

    // one signal point of the quickstart pallet
    let pallet = pallet::generate(&library::config_quickstart());
    let patch = &pallet.patchset.patches[0];
    let ws = Workspace::from_json(&patch.apply_to(&pallet.bkg_workspace).map_err(|e| e.to_string())?)
        .map_err(|e| e.to_string())?;
    let class = dense::pick_class(&ws, &classes).map_err(|e| e.to_string())?;
    let model = dense::compile(&ws, class).map_err(|e| e.to_string())?;

    // --- 1. upper-limit scan on mu ----------------------------------------
    println!("== upper-limit scan (patch '{}') ==", patch.name);
    let grid = default_mu_grid(class.mu_max, 16);
    let ul = upper_limit_scan(&model, &grid);
    for (mu, cls, _) in ul.scan.iter().take(6) {
        println!("  mu = {mu:6.3}  CLs = {cls:.4}");
    }
    println!("  ...");
    match ul.obs {
        Some(x) => println!("  observed 95% CL upper limit: mu < {x:.3}"),
        None => println!("  no crossing in scan range"),
    }
    if let (Some(lo), Some(med), Some(hi)) = (ul.exp[0], ul.exp[2], ul.exp[4]) {
        println!("  expected: {med:.3} (+{:.3} / -{:.3})", hi - med, med - lo);
    }

    // --- 2. toys vs asymptotics --------------------------------------------
    println!("\n== toy-based CLs vs asymptotics (mu = 1) ==");
    let asym = NativeFitter::new(&model).hypotest(1.0);
    let toys = hypotest_toys(&model, 1.0, 300, 0x70b5);
    println!("  asymptotic CLs = {:.4}", asym.cls_obs);
    println!("  toys (n=300)   = {:.4}  (CLsb {:.4} / CLb {:.4})", toys.cls_obs, toys.clsb, toys.clb);

    // --- 3. statistical combination -----------------------------------------
    println!("\n== statistical combination of two analyses ==");
    let pallet2 = pallet::generate(&pyhf_faas::pallet::AnalysisConfig {
        seed: 0xbeef,
        ..library::config_quickstart()
    });
    let patch2 = &pallet2.patchset.patches[0];
    let ws2 = Workspace::from_json(&patch2.apply_to(&pallet2.bkg_workspace).map_err(|e| e.to_string())?)
        .map_err(|e| e.to_string())?;
    let ws2 = prefix_channels(&ws2, "ana2_");
    let joint = combine(&ws, &ws2).map_err(|e| e.to_string())?;
    let jclass = dense::pick_class(&joint, &classes).map_err(|e| e.to_string())?;
    let jmodel = dense::compile(&joint, jclass).map_err(|e| e.to_string())?;

    let h1 = NativeFitter::new(&model).hypotest(1.0);
    let m2 = dense::compile(&ws2, class).map_err(|e| e.to_string())?;
    let h2 = NativeFitter::new(&m2).hypotest(1.0);
    let hj = NativeFitter::new(&jmodel).hypotest(1.0);
    println!("  analysis 1: qmu_A = {:.3}  CLs_exp(med) = {:.4}", h1.qmu_a, h1.cls_exp[2]);
    println!("  analysis 2: qmu_A = {:.3}  CLs_exp(med) = {:.4}", h2.qmu_a, h2.cls_exp[2]);
    println!("  combined  : qmu_A = {:.3}  CLs_exp(med) = {:.4}  (class {})",
        hj.qmu_a, hj.cls_exp[2], jclass.name);
    assert!(hj.qmu_a > h1.qmu_a && hj.qmu_a > h2.qmu_a, "combination must add power");
    println!("\ncombination adds exclusion power, as the paper's pMSSM/combination outlook expects.");
    Ok(())
}
